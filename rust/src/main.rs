//! `opengemm` — command-line launcher for the OpenGeMM reproduction
//! platform.
//!
//! Subcommands map one-to-one to the paper's experiments (DESIGN.md
//! experiment index):
//!
//! ```text
//! opengemm simulate  --shape MxKxN [--arch 1..4] [--repeats R] [--layout L]
//! opengemm ablation  [--workloads N] [--seed S] [--repeats R]      # Fig. 5
//! opengemm dnn       [--bert-seq S]                                # Table 2
//! opengemm area-power                                              # Fig. 6
//! opengemm sota                                                    # Table 3
//! opengemm compare-gemmini [--repeats R]                           # Fig. 7
//! opengemm sweep     [--processes P]        # sharded Fig. 5-style sweep
//! opengemm lint      [--target SUBSTR]     # static verifier over all experiment grids
//! opengemm serve     [--workload W]        # sustained-traffic serving harness
//! opengemm verify    [--artifacts DIR]     # simulator vs PJRT golden model
//! opengemm info      [--config FILE.toml]  # show an instance's parameters
//! ```
//!
//! ## Serving harness (`opengemm serve`)
//!
//! Simulates the platform as an inference service: a seeded arrival
//! process (open-loop Poisson or closed-loop clients) pushes BERT
//! encoder-layer / ResNet-18 requests through a virtual-time queueing
//! model with a pluggable batching policy, and the report carries
//! p50/p90/p95/p99/max per-request latency. With `--devices N` the
//! harness simulates a fleet behind a placement policy, with
//! deterministic fault injection, timeout failover, hedging and SLO
//! load shedding. The JSON output is a pure function of (config,
//! options, seed) — two runs with the same seed are byte-identical,
//! faults included (the CI `serve-smoke` and `fleet-smoke` lanes diff
//! them):
//!
//! ```text
//! opengemm serve --workload bert --requests 64 --rate 500 --seed 7 --json
//! opengemm serve --workload mixed --arrival closed --clients 8 --batching size --batch 4
//! opengemm serve --devices 4 --placement least-work --fail-device 2@50000 --json
//! ```
//!
//! ## Distributed sweeps (`opengemm sweep`)
//!
//! Every sweep runs through the fault-tolerant dispatch scheduler
//! (`coordinator::dispatch`): a pluggable transport moves shards to
//! executors, and retry / straggler policy sits on top. All transports
//! produce byte-identical merged JSON (stdout, or `--out FILE`):
//!
//! ```text
//! # in-process transport (default)
//! opengemm sweep --workloads 40 --variants 2 --repeats 2 > a.json
//!
//! # subprocess transport: shard files + 2 worker processes of this
//! # same binary, scheduled with retry (--retries) and straggler
//! # re-dispatch (--straggler-factor)
//! opengemm sweep --workloads 40 --variants 2 --repeats 2 --processes 2 > b.json
//! diff a.json b.json   # empty: merge(shards) == unsharded run
//!
//! # spool-dir transport: shards are published into a shared directory;
//! # any host watching it executes them (the cross-host primitive)
//! opengemm sweep --spool-serve /mnt/spool            # on each worker host
//! opengemm sweep --workloads 40 --transport spool --spool /mnt/spool
//!
//! # explicit worker: run one serialized shard by hand
//! opengemm sweep --shard /tmp/v0_s0.shard.json --out /tmp/v0_s0.result.json
//!
//! # content-addressed result cache: the warm re-run simulates zero
//! # jobs and emits byte-identical JSON (the CI cache-smoke lane
//! # asserts both); --cache-verify re-simulates hits and hard-errors
//! # if a cached outcome diverges
//! opengemm sweep --workloads 40 --cache /tmp/gemm.cache > c.json
//! opengemm sweep --workloads 40 --cache /tmp/gemm.cache > d.json
//! diff c.json d.json
//! opengemm sweep --workloads 40 --cache /tmp/gemm.cache --cache-verify > /dev/null
//! ```

use std::path::{Path, PathBuf};
use std::sync::atomic::AtomicBool;
use std::time::Duration;

use opengemm::util::error::Result;
use opengemm::{anyhow, bail};

use opengemm::analysis::{self, LintReport, Severity, TargetReport};
use opengemm::compiler::{GemmShape, Layout};
use opengemm::config::{DmaParams, Mechanisms, PlatformConfig, MAX_CORES};
use opengemm::coordinator::cache::ResultCache;
use opengemm::coordinator::dispatch::{
    dispatch_plan_cached, spool_worker_loop, write_atomically, DispatchOptions, DispatchReport,
    FaultInjector, InProcess, SpoolDir, SpoolWorkerOptions, Subprocess, Transport,
};
use opengemm::coordinator::shard::{
    resolve_worker_override, Shard, SweepOptions, SweepPlan, SweepResult,
};
use opengemm::coordinator::{Coordinator, JobRequest};
use opengemm::experiments::fig5::{variant_config, variant_specs};
use opengemm::experiments::fig5::fig5_ablation_cached;
use opengemm::experiments::{
    fig6_area_power, fig7_gemmini, table2_dnn, table3_sota, Fig5Options, Fig6Options, Fig7Options,
    Table2Options,
};
use opengemm::model::prefilter;
use opengemm::power::PowerModel;
use opengemm::runtime::Runtime;
use opengemm::serve::{
    ms_to_cycles, run_serve, ArrivalSpec, BatchPolicy, FaultSpec, PlacementPolicy, ServeOptions,
    WorkloadSpec,
};
use opengemm::util::cli::Args;
use opengemm::util::json::Json;
use opengemm::util::rng::Pcg32;
use opengemm::workloads::{
    bert_base, mobilenet_v2, mobilenet_v2_host_dw, random_suite, resnet18, vit_b16,
};

const USAGE: &str = "\
opengemm — cycle-accurate OpenGeMM platform (ASPDAC'25 reproduction)

USAGE:
  opengemm <subcommand> [flags]

SUBCOMMANDS:
  simulate          run one GeMM through the platform simulator
                    --shape MxKxN  --arch 1|2|3|4  --repeats N
                    --layout row|tiled|interleaved  --functional
  ablation          Fig. 5: mechanism ablation over random workloads
                    --workloads N  --seed S  --repeats N  --workers N
                    --prefilter analytical [--confirm-top K]
                                   (simulate only the top-K variants of
                                    the closed-form analytical ranking;
                                    pruned rows report predicted stats)
                    --cache DIR    (content-addressed result cache; a
                                    re-run simulates only unseen jobs)
                    --cache-verify (with --cache: re-simulate hits and
                                    hard-error if a cached outcome
                                    diverges)
  dnn               Table 2: DNN benchmark (MobileNetV2/ResNet18/ViT/BERT)
                    --bert-seq N  --workers N
  area-power        Fig. 6: area & power breakdown, TOPS/W
  sota              Table 3: state-of-the-art comparison
  compare-gemmini   Fig. 7: normalized throughput vs Gemmini OS/WS
                    --repeats N
  sweep             sharded Fig. 5-style sweep under the fault-tolerant
                    dispatch scheduler; merged JSON on stdout
                    --workloads N  --seed S  --repeats N
                    --variants V   (first V rungs of the Fig. 5 ladder)
                    --shards S     (shards per variant; default P,
                                    or 8 under the spool transport)
                    --workers N    (threads per shard coordinator)
                    --transport inprocess|subprocess|spool
                    --processes P  (P>1 implies subprocess: P workers;
                                    shards default to P, or 8 for spool)
                    --spool DIR    (implies spool: publish shards into a
                                    shared dir served by other hosts)
                    --retries N    (per-shard retry budget; default 1)
                    --straggler-factor F (speculatively re-dispatch a
                                    shard running longer than F x the
                                    median shard wall time; 0 = off)
                    --spool-timeout-secs S  --spool-poll-ms MS
                    --report FILE  (dispatch provenance JSON: attempts,
                                    retries, stragglers, duplicates)
                    --inject-fail IDX  (testing: fail the first dispatch
                                        of shard IDX once)
                    --out FILE     (write instead of stdout)
                    --keep-shards DIR  (subprocess: leave shard/result
                                        files in DIR for other hosts)
                    --prefilter analytical|none  (rank the whole job
                                    grid with the closed-form cost
                                    model in the driver and dispatch
                                    only the frontier variants; the
                                    merged JSON carries predicted stats
                                    for every variant, simulated stats
                                    + per-job prediction error for the
                                    confirmed ones, and a `prefilter`
                                    header with fraction_simulated and
                                    the analytical ranking)
                    --confirm-top K   (frontier size in variants;
                                       default 1)
                    --confirm-frac F  (frontier as a fraction of the
                                       variant grid, rounded up;
                                       mutually exclusive with
                                       --confirm-top)
                    --cache DIR    (content-addressed result cache: a
                                    warm re-run dispatches only jobs
                                    never simulated before, and a spool
                                    sweep re-run after a driver crash
                                    claims already-published results
                                    instead of re-running their shards)
                    --cache-verify (with --cache: re-simulate every hit
                                    and hard-error on divergence — a
                                    determinism regression drill)
                    --cache-gc-max-entries N  (with --cache: after each
                                    publish, evict the oldest entries
                                    until at most N remain; .poison
                                    quarantine files are never
                                    collected, only counted in the
                                    dispatch report)
                    --no-lint      (skip the static-verifier admission
                                    gate; by default every compilable
                                    job is checked pre-dispatch and an
                                    illegal one fails the sweep loudly)
                    worker mode: --shard FILE [--out FILE] [--workers N]
                    spool executor mode: --spool-serve DIR [--workers N]
                                         [--max-shards N] [--poll-ms MS]
  lint              static verifier: check every experiment workload's
                    compiled schedules, CSR programs, and SPM placements
                    against the platform invariants, without simulating
                    (codes A001..A013; see ROADMAP.md for the catalog)
                    --target SUBSTR  (only targets whose name contains
                                      SUBSTR: fig5, table2, fig7, serve,
                                      or a specific rung/model)
                    --workloads N  --seed S  --repeats N  (fig5 grid)
                    --bert-seq N  --max-repeats N         (table2 grid)
                    --seqs 64,128,...  --repeat-cap R     (serve grids)
                    --json         (opengemm-lint-report-v1 on stdout)
                    --out FILE     (also write the JSON report to FILE)
                    exit status: non-zero iff any error-severity
                    diagnostic was reported
  serve             sustained-traffic serving harness; latency percentiles
                    --workload bert|bert-large|resnet18|mixed
                    --requests N   --seed S
                    --arrival poisson|closed
                    --rate RPS     (poisson offered load, req/s)
                    --clients N  --think-ms MS   (closed loop)
                    --batching immediate|size|deadline
                    --batch N  --deadline-ms MS
                    --overhead-cycles C  (per-batch dispatch cost)
                    --seqs 64,128,...    (BERT sequence-length mix)
                    --repeat-cap R  --workers N
                    --devices N    (simulated devices behind the router)
                    --placement round-robin|least-work|affinity
                    --fail-device IDX@CYCLE      (fail-stop injection;
                                    comma-separate for several)
                    --degrade-device IDX@CYCLE:FACTOR  (slow-down
                                    injection, FACTOR >= 1)
                    --slo-ms MS    (shed arrivals whose predicted wait
                                    exceeds the SLO; reported, never
                                    silent)
                    --hedge        (hedged re-issue past the p99 window;
                                    first completion wins, loser's
                                    cycles counted as waste)
                    --retries N    (failover re-dispatch budget per
                                    batch; default 2)
                    --cache DIR    (persist ServiceModel measurements:
                                    a re-run with the same platform
                                    prices known shapes from the cache)
                    --cache-verify (with --cache: re-simulate hits and
                                    hard-error on divergence)
                    --json         (JSON report on stdout, not the table)
                    --out FILE     (also write the JSON report to FILE)
  verify            functional equivalence: simulator vs AOT artifacts
                    --artifacts DIR
  info              print platform instance parameters
                    --config FILE.toml

GLOBAL FLAGS:
  --no-fast-forward run the simulator in per-cycle lockstep instead of
                    the event-driven cycle-skipping engine (slow; the
                    two are verified cycle-exact against each other)
  --cores N         GeMM cores sharing the banked SPM (1..=8, default 1;
                    calls dispatch round-robin, each core owns an equal
                    SPM partition). Driver-side: a sweep worker or
                    spool executor rejects it (shards embed a platform)
  --dma-chunk W     stage operands through the modeled background-memory
                    DMA engine in W-word bursts (off by default; the
                    DMA contends for SPM banks like any streamer)
  --dma-latency L   per-burst background-memory latency in cycles
                    (default 8; requires --dma-chunk)

ENVIRONMENT:
  OPENGEMM_WORKERS  override the coordinator's auto-sized worker pool
                    (no upper clamp; `--workers` flags still win; an
                    unparsable or zero value is a hard error, not a
                    silent fallback to auto-sizing). Worker-pool
                    precedence on a sweep worker host:
                    --workers > OPENGEMM_WORKERS > shard file > auto

EXAMPLE — a sweep sharded across 2 processes is byte-identical to the
same sweep in one process:
  opengemm sweep --workloads 40 --variants 2 --repeats 2              > a.json
  opengemm sweep --workloads 40 --variants 2 --repeats 2 --processes 2 > b.json
  diff a.json b.json
";

fn mechanisms_for(arch: usize) -> Result<Mechanisms> {
    Ok(match arch {
        1 => Mechanisms::BASELINE,
        2 => Mechanisms::CPL,
        3 => Mechanisms::CPL_BUF,
        4 => Mechanisms::ALL,
        a => bail!("--arch must be 1..4, got {a}"),
    })
}

fn layout_for(name: &str) -> Result<Layout> {
    Ok(match name {
        "row" => Layout::RowMajor,
        "tiled" => Layout::TiledContiguous,
        "interleaved" => Layout::TiledInterleaved,
        other => bail!("--layout must be row|tiled|interleaved, got {other}"),
    })
}

fn load_config(args: &Args) -> Result<PlatformConfig> {
    let mut cfg = match args.get("config") {
        None => PlatformConfig::case_study(),
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            PlatformConfig::from_toml(&text).map_err(|e| anyhow!("{e}"))?
        }
    };
    apply_platform_knobs(args, &mut cfg)?;
    Ok(cfg)
}

/// Apply the `--cores N` / `--dma-chunk W` / `--dma-latency L`
/// platform overrides. Every subcommand loads its config through
/// [`load_config`], so a malformed knob is a hard error on every path
/// — same fail-loudly policy as `--transport` and `--prefilter` — and
/// an override that breaks the instance (e.g. partitions smaller than
/// the minimum working set) fails re-validation before any work runs.
fn apply_platform_knobs(args: &Args, cfg: &mut PlatformConfig) -> Result<()> {
    let mut touched = false;
    if args.get("cores").is_some() {
        let cores = args.usize_or("cores", 1)?;
        if !(1..=MAX_CORES).contains(&cores) {
            bail!("--cores must be 1..={MAX_CORES}, got {cores}");
        }
        cfg.cores = cores;
        touched = true;
    }
    if args.get("dma-latency").is_some() && args.get("dma-chunk").is_none() {
        bail!("--dma-latency needs --dma-chunk WORDS (no DMA engine to configure)");
    }
    if args.get("dma-chunk").is_some() {
        let chunk_words = args.usize_or("dma-chunk", 0)?;
        if chunk_words == 0 {
            bail!("--dma-chunk must be a positive word count, got 0");
        }
        let latency = args.u64_or("dma-latency", 8)?;
        cfg.dma = Some(DmaParams { chunk_words, latency });
        touched = true;
    }
    if touched {
        cfg.validate().map_err(|e| anyhow!("platform overrides: {e}"))?;
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let shape = args.shape_or("shape", (64, 64, 64))?;
    let shape = GemmShape::new(shape.0, shape.1, shape.2);
    let mech = mechanisms_for(args.usize_or("arch", 4)?)?;
    let repeats = args.usize_or("repeats", 10)? as u32;
    let layout = match args.get("layout") {
        Some(l) => layout_for(l)?,
        None => {
            if mech.strided_layout {
                Layout::TiledInterleaved
            } else {
                Layout::RowMajor
            }
        }
    };
    let functional = args.has("functional");

    let coord =
        Coordinator::new(cfg.clone()).with_fast_forward(args.enabled_unless_no("fast-forward"));
    let operands = if functional {
        let mut rng = Pcg32::seeded(args.u64_or("seed", 42)?);
        let mut a = vec![0i8; shape.m * shape.k];
        let mut b = vec![0i8; shape.k * shape.n];
        rng.fill_i8(&mut a);
        rng.fill_i8(&mut b);
        Some((a, b))
    } else {
        None
    };
    let req = JobRequest { shape, layout, mechanisms: mech, repeats, operands };
    let r = coord.run_one(&req).map_err(|e| anyhow!(e))?;
    println!("shape          ({}, {}, {})", shape.m, shape.k, shape.n);
    println!("arch           {}", mech.label());
    println!("layout         {layout:?}  repeats {repeats}");
    println!("total cycles   {}", r.metrics.total_cycles);
    println!("compute cycles {}", r.metrics.compute_cycles);
    println!(
        "stalls         A {} / B {} / out {}",
        r.metrics.stall_input_a, r.metrics.stall_input_b, r.metrics.stall_output
    );
    println!("host instret   {}", r.metrics.host_instret);
    println!(
        "SU {:.4}  TU {:.4}  OU {:.4}  (kernel TU {:.4})",
        r.report.spatial,
        r.report.temporal,
        r.report.overall,
        r.metrics.kernel_utilization()
    );
    let gops = r.report.achieved_gops(shape.ops() * repeats as u64, cfg.freq_mhz);
    println!("achieved       {gops:.2} GOPS of {:.1} peak", cfg.peak_gops());
    if let Some(c) = r.c {
        let checksum: i64 = c.iter().map(|&v| v as i64).sum();
        println!("functional     C checksum {checksum}");
    }
    Ok(())
}

/// Parse `--cache DIR` / `--cache-verify` / `--cache-gc-max-entries`
/// into an opened result cache. A cache modifier without a store to
/// apply it to is a hard error — same fail-loudly policy as
/// `--transport` and `--prefilter`.
fn open_cache(args: &Args) -> Result<Option<ResultCache>> {
    let verify = args.has("cache-verify");
    let gc_flag = args.get("cache-gc-max-entries").is_some();
    let gc_max = if gc_flag { args.usize_or("cache-gc-max-entries", 0)? } else { 0 };
    match args.get("cache") {
        Some(dir) => Ok(Some(
            ResultCache::persistent(Path::new(dir))
                .map_err(|e| anyhow!(e))?
                .with_verify(verify)
                .with_gc_max_entries(gc_max),
        )),
        None if verify => bail!("--cache-verify needs --cache DIR (no cache to verify against)"),
        None if gc_flag => {
            bail!("--cache-gc-max-entries needs --cache DIR (no store to collect)")
        }
        None => Ok(None),
    }
}

fn cmd_ablation(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let cache = open_cache(args)?;
    let opts = Fig5Options {
        seed: args.u64_or("seed", 2024)?,
        workloads: args.usize_or("workloads", 500)?,
        repeats: args.usize_or("repeats", 10)? as u32,
        workers: args.usize_or("workers", 0)?,
        shards: args.usize_or("shards", 1)?,
        fast_forward: args.enabled_unless_no("fast-forward"),
        prefilter_confirm_top: if prefilter_enabled(args)? {
            Some(args.usize_or("confirm-top", 1)?)
        } else {
            None
        },
    };
    eprintln!(
        "running {} workloads x 10 repeats x 6 variants ...",
        opts.workloads
    );
    let res = fig5_ablation_cached(&cfg, opts, cache.as_ref()).map_err(|e| anyhow!(e))?;
    println!("{}", res.render());
    maybe_write(args, "fig5", &res.render())
}

fn cmd_dnn(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let opts = Table2Options {
        bert_seq: args.usize_or("bert-seq", 512)?,
        workers: args.usize_or("workers", 0)?,
        max_repeats: args.usize_or("max-repeats", 10)? as u32,
        fast_forward: args.enabled_unless_no("fast-forward"),
    };
    let res = table2_dnn(&cfg, opts);
    println!("{}", res.render());
    maybe_write(args, "table2", &res.render())
}

fn cmd_area_power(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let opts = Fig6Options { fast_forward: args.enabled_unless_no("fast-forward") };
    let res = fig6_area_power(&cfg, opts);
    println!("{}", res.render());
    maybe_write(args, "fig6", &res.render())
}

fn cmd_sota(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let res = table3_sota(&cfg);
    println!("{}", res.render());
    maybe_write(args, "table3", &res.render())
}

fn cmd_compare_gemmini(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let opts = Fig7Options {
        repeats: args.usize_or("repeats", 10)? as u32,
        workers: args.usize_or("workers", 0)?,
        fast_forward: args.enabled_unless_no("fast-forward"),
    };
    let res = fig7_gemmini(&cfg, opts);
    println!("{}", res.render());
    maybe_write(args, "fig7", &res.render())
}

/// One variant's merged slice of a `sweep` run.
struct SweepVariantOutcome {
    label: &'static str,
    depth: usize,
    mechanisms: Mechanisms,
    result: SweepResult,
}

/// The merged sweep document. Everything in here is a deterministic
/// function of the simulated work (no wall-clock, hosts, or process
/// counts), so driver-mode and single-process runs serialize
/// byte-identically — the property the CI `sweep-smoke` lane diffs.
fn sweep_doc(
    seed: u64,
    workloads: usize,
    repeats: u32,
    variants: &[SweepVariantOutcome],
) -> Json {
    let docs: Vec<Json> = variants
        .iter()
        .map(|v| {
            Json::obj(vec![
                ("label", Json::str(v.label)),
                ("d_stream", Json::num(v.depth as f64)),
                ("mechanisms", v.mechanisms.to_json()),
                ("median_overall", Json::num(median_overall_of(&v.result))),
                ("result", v.result.to_json()),
            ])
        })
        .collect();
    Json::obj(vec![
        ("sweep", Json::str("fig5")),
        ("seed", Json::num(seed as f64)),
        ("workloads", Json::num(workloads as f64)),
        ("repeats", Json::num(repeats as f64)),
        ("variants", Json::Arr(docs)),
    ])
}

/// Median simulated overall utilization of one variant's outcomes —
/// the statistic both the Fig. 5 table and the analytical ranking use,
/// so the prefiltered and unfiltered documents are comparable on the
/// same key.
fn median_overall_of(result: &SweepResult) -> f64 {
    let mut overall: Vec<f64> = result
        .outcomes
        .iter()
        .filter_map(|o| o.as_ref().ok().map(|r| r.report.overall))
        .collect();
    overall.sort_by(f64::total_cmp);
    prefilter::percentile(&overall, 0.5)
}

/// The merged document of a prefiltered sweep: predicted stats for
/// every ladder rung, simulated result + per-job prediction error for
/// the confirmed frontier, and a `prefilter` header carrying the
/// analytical ranking and the simulated fraction of the grid. Like
/// [`sweep_doc`], a deterministic function of the simulated work.
fn sweep_doc_prefiltered(
    seed: u64,
    workloads: usize,
    repeats: u32,
    ladder: &[(&'static str, Mechanisms, usize)],
    ranked: &[prefilter::VariantPrediction],
    results: &[(usize, SweepResult)],
) -> Json {
    let grid_jobs = workloads * ladder.len();
    let simulated_jobs: usize = results.iter().map(|(_, r)| r.outcomes.len()).sum();
    let mut best: Option<(f64, &'static str)> = None;
    let mut docs: Vec<Json> = Vec::with_capacity(ladder.len());
    for (variant, &(label, mechanisms, depth)) in ladder.iter().enumerate() {
        let mut fields = vec![
            ("label", Json::str(label)),
            ("d_stream", Json::num(depth as f64)),
            ("mechanisms", mechanisms.to_json()),
            ("predicted", ranked[variant].stats_json()),
        ];
        match results.iter().find(|(v, _)| *v == variant) {
            Some((_, result)) => {
                let median = median_overall_of(result);
                let better = match best {
                    None => true,
                    Some((b, _)) => median > b,
                };
                if better {
                    best = Some((median, label));
                }
                let errors = prefilter::job_errors(&ranked[variant].predictions, result);
                let error_docs: Vec<Json> = errors
                    .iter()
                    .map(|e| match e {
                        Some(x) => Json::num(*x),
                        None => Json::Null,
                    })
                    .collect();
                fields.push(("median_overall", Json::num(median)));
                fields.push(("result", result.to_json()));
                fields.push((
                    "prediction_error",
                    match prefilter::ErrorSummary::from_errors(&errors) {
                        Some(s) => s.to_json(),
                        None => Json::Null,
                    },
                ));
                fields.push(("cycle_errors", Json::arr(error_docs)));
            }
            None => fields.push(("result", Json::Null)),
        }
        docs.push(Json::obj(fields));
    }
    let order = prefilter::frontier(ranked, ranked.len());
    let fraction = simulated_jobs as f64 / grid_jobs.max(1) as f64;
    let ranking: Vec<Json> = order.iter().map(|&i| Json::str(ladder[i].0)).collect();
    // Grid points the static verifier rejected never enter the
    // ranking; they are named here so a pruned variant is visibly
    // *illegal*, not merely unconfirmed.
    let rejected: Vec<Json> = ranked
        .iter()
        .enumerate()
        .filter(|(_, r)| r.statically_rejected.is_some())
        .map(|(i, _)| Json::str(ladder[i].0))
        .collect();
    Json::obj(vec![
        ("sweep", Json::str("fig5")),
        ("seed", Json::num(seed as f64)),
        ("workloads", Json::num(workloads as f64)),
        ("repeats", Json::num(repeats as f64)),
        (
            "prefilter",
            Json::obj(vec![
                ("mode", Json::str("analytical")),
                ("grid_jobs", Json::num(grid_jobs as f64)),
                ("simulated_jobs", Json::num(simulated_jobs as f64)),
                ("fraction_simulated", Json::num(fraction)),
                ("ranking", Json::arr(ranking)),
                ("statically_rejected", Json::arr(rejected)),
                (
                    "top1_simulated",
                    match best {
                        Some((_, label)) => Json::str(label),
                        None => Json::Null,
                    },
                ),
            ]),
        ),
        ("variants", Json::Arr(docs)),
    ])
}

/// The worker host's `--workers` flag, if present. Feeds
/// [`resolve_worker_override`]'s CLI slot; `Some(0)` resets to the
/// host's default policy (`OPENGEMM_WORKERS`, else machine-sized)
/// instead of the shard-embedded value.
fn cli_workers(args: &Args) -> Result<Option<usize>> {
    match args.get("workers") {
        Some(_) => Ok(Some(args.usize_or("workers", 0)?)),
        None => Ok(None),
    }
}

/// Worker mode: run one serialized shard, emit its result as JSON.
/// The worker pool is sized for THIS host: CLI `--workers` >
/// `OPENGEMM_WORKERS` > the shard-embedded origin-host value > auto.
fn sweep_worker(args: &Args, shard_path: &str) -> Result<()> {
    let mut shard = Shard::read_file(Path::new(shard_path)).map_err(|e| anyhow!(e))?;
    let env = std::env::var("OPENGEMM_WORKERS").ok();
    shard.options.workers =
        resolve_worker_override(cli_workers(args)?, env.as_deref(), shard.options.workers)
            .map_err(|e| anyhow!(e))?;
    let pool = match shard.options.workers {
        0 => "auto".to_string(),
        n => n.to_string(),
    };
    eprintln!(
        "worker: shard {}/{} — {} jobs, {} worker thread(s)",
        shard.shard_index + 1,
        shard.num_shards,
        shard.requests.len(),
        pool
    );
    let result = shard.run();
    let text = result.to_json().pretty();
    match args.get("out") {
        // temp-file + rename: a spool driver polling for this file must
        // never observe a partial write
        Some(out) => write_atomically(Path::new(out), &text).map_err(|e| anyhow!(e))?,
        None => println!("{text}"),
    }
    Ok(())
}

/// Spool executor mode: watch a shared directory, claim and run every
/// shard published into it, publish the result files. Runs until
/// killed (or `--max-shards N`); any number of hosts may serve the
/// same directory.
fn sweep_spool_serve(args: &Args, dir: &str) -> Result<()> {
    let opts = SpoolWorkerOptions {
        poll: Duration::from_millis(args.u64_or("poll-ms", 25)?.max(1)),
        max_shards: args.usize_or("max-shards", 0)?,
        cli_workers: cli_workers(args)?,
    };
    eprintln!(
        "spool worker: watching {dir} ({}; stop with Ctrl-C)",
        match opts.max_shards {
            0 => "until killed".to_string(),
            n => format!("up to {n} shard(s)"),
        }
    );
    let stop = AtomicBool::new(false);
    let served = spool_worker_loop(Path::new(dir), &opts, &stop).map_err(|e| anyhow!(e))?;
    eprintln!("spool worker: served {served} shard(s)");
    Ok(())
}

/// Which transport a sweep uses: explicit `--transport` wins, else
/// `--spool DIR` implies the spool transport, `--processes P > 1` the
/// subprocess transport, and everything else runs in-process.
fn transport_name(args: &Args, processes: usize) -> Result<&'static str> {
    let implied = if args.has("spool") {
        "spool"
    } else if processes > 1 {
        "subprocess"
    } else {
        "inprocess"
    };
    match args.get("transport") {
        None => Ok(implied),
        Some("inprocess") => Ok("inprocess"),
        Some("subprocess") => Ok("subprocess"),
        Some("spool") => Ok("spool"),
        Some(other) => bail!("--transport must be inprocess|subprocess|spool, got {other:?}"),
    }
}

/// Whether `--prefilter` asks for the analytical DSE prefilter.
/// Unknown names are a hard error with the valid set listed — same
/// policy as `--transport` and `OPENGEMM_WORKERS`.
fn prefilter_enabled(args: &Args) -> Result<bool> {
    match args.get("prefilter") {
        None | Some("none") => Ok(false),
        Some("analytical") => Ok(true),
        Some(other) => bail!("--prefilter must be none|analytical, got {other:?}"),
    }
}

/// Parse the frontier-size knobs. Both are validated here even when the
/// prefilter is off, so a typo'd flag never silently degrades to a full
/// simulation of the grid.
fn confirm_knobs(args: &Args) -> Result<(Option<usize>, Option<f64>)> {
    let top = match args.get("confirm-top") {
        Some(_) => Some(args.usize_or("confirm-top", 1)?),
        None => None,
    };
    let frac = match args.get("confirm-frac") {
        Some(_) => Some(args.f64_or("confirm-frac", 0.0)?),
        None => None,
    };
    if let Some(f) = frac {
        if !f.is_finite() || f <= 0.0 || f > 1.0 {
            bail!("--confirm-frac must be in (0, 1], got {f}");
        }
    }
    if top.is_some() && frac.is_some() {
        bail!("--confirm-top and --confirm-frac are mutually exclusive");
    }
    Ok((top, frac))
}

fn cmd_sweep(args: &Args) -> Result<()> {
    // Name-valued flags are validated before any mode dispatch: a
    // worker or spool-executor invocation with a mistyped --transport
    // or --prefilter must fail loudly instead of running with the flag
    // silently ignored.
    let processes = args.usize_or("processes", 1)?;
    let transport = transport_name(args, processes)?;
    let prefilter_on = prefilter_enabled(args)?;
    let (confirm_top, confirm_frac) = confirm_knobs(args)?;
    if !prefilter_on && (confirm_top.is_some() || confirm_frac.is_some()) {
        bail!("--confirm-top/--confirm-frac need --prefilter analytical");
    }
    let caching = args.has("cache") || args.has("cache-verify");
    if args.has("cache-verify") && !args.has("cache") {
        bail!("--cache-verify needs --cache DIR (no cache to verify against)");
    }
    // Platform-override knobs are driver-side: worker and spool
    // executors take their platform from the shard file, so a --cores
    // or DMA flag there would be silently ignored — fail loudly
    // instead, before either early return below.
    let platform_knobs =
        ["cores", "dma-chunk", "dma-latency"].iter().any(|k| args.get(k).is_some());

    // worker mode: run one shard file and exit
    if let Some(shard_path) = args.get("shard") {
        if caching {
            bail!("--cache/--cache-verify apply to the sweep driver, not worker mode (--shard)");
        }
        if platform_knobs {
            bail!(
                "--cores/--dma-chunk/--dma-latency apply to the sweep driver, \
                 not worker mode (--shard embeds its platform)"
            );
        }
        return sweep_worker(args, shard_path);
    }
    // spool executor mode: serve a shared spool directory
    if let Some(dir) = args.get("spool-serve") {
        if caching {
            bail!(
                "--cache/--cache-verify apply to the sweep driver, \
                 not the spool executor (--spool-serve)"
            );
        }
        if platform_knobs {
            bail!(
                "--cores/--dma-chunk/--dma-latency apply to the sweep driver, \
                 not the spool executor (--spool-serve shards embed their platform)"
            );
        }
        return sweep_spool_serve(args, dir);
    }
    // One persistent store shared by every variant of the sweep: keys
    // are content-addressed over (config, options, request), so
    // variants never collide in it.
    let cache = open_cache(args)?;

    let cfg = load_config(args)?;
    let seed = args.u64_or("seed", 2024)?;
    let workloads = args.usize_or("workloads", 500)?;
    let repeats = args.u64_or("repeats", 10)?;
    let repeats =
        u32::try_from(repeats).map_err(|_| anyhow!("--repeats {repeats} out of u32 range"))?;
    let ladder = variant_specs();
    let n_variants = args.usize_or("variants", ladder.len())?.clamp(1, ladder.len());
    // Spool sweeps distribute across an unknown number of executor
    // hosts, and retry/straggler granularity is per shard — a
    // single-shard spool sweep would serialize onto one executor and
    // make every fault re-run the whole variant. Default to a real
    // split there; elsewhere one shard per worker process.
    let default_shards = match transport {
        "spool" => 8,
        _ => processes.max(1),
    };
    let sweep_opts = SweepOptions {
        shards: args.usize_or("shards", default_shards)?,
        workers: args.usize_or("workers", 0)?,
        fast_forward: args.enabled_unless_no("fast-forward"),
        lint: args.enabled_unless_no("lint"),
        ..Default::default()
    };

    // scheduler policy
    let retries = args.u64_or("retries", 1)?;
    let retries =
        u32::try_from(retries).map_err(|_| anyhow!("--retries {retries} out of u32 range"))?;
    let straggler_factor = args.f64_or("straggler-factor", 0.0)?;
    if !straggler_factor.is_finite() || straggler_factor < 0.0 {
        bail!("--straggler-factor must be a finite non-negative number, got {straggler_factor}");
    }
    let inject_fail = match args.get("inject-fail") {
        Some(_) => Some(args.usize_or("inject-fail", 0)?),
        None => None,
    };
    let spool_poll = Duration::from_millis(args.u64_or("spool-poll-ms", 25)?.max(1));
    let spool_timeout = Duration::from_secs(args.u64_or("spool-timeout-secs", 600)?.max(1));

    // `--keep-shards DIR` leaves the subprocess transport's shard and
    // result files behind — the hand-a-shard-to-another-host workflow
    // needs them to survive the run. Without it, a private temp dir is
    // cleaned up at the end.
    let (work_dir, ephemeral) = match args.get("keep-shards") {
        Some(dir) => (PathBuf::from(dir), false),
        None => (
            std::env::temp_dir().join(format!("opengemm-sweep-{}", std::process::id())),
            true,
        ),
    };

    let shapes = random_suite(seed, workloads);
    let ladder = &ladder[..n_variants];
    eprintln!(
        "sweep: {} workloads x {} variants, {} shard(s)/variant, {} transport, \
         {} retr{} per shard",
        workloads,
        ladder.len(),
        sweep_opts.shards.clamp(1, workloads.max(1)),
        transport,
        retries,
        if retries == 1 { "y" } else { "ies" },
    );

    // The full job grid, one variant per ladder rung. With the
    // analytical prefilter, this is also what gets ranked.
    let grid: Vec<prefilter::GridVariant> = ladder
        .iter()
        .map(|&(label, mech, depth)| prefilter::GridVariant {
            label: label.to_string(),
            cfg: variant_config(&cfg, depth),
            requests: shapes.iter().map(|&s| JobRequest::timing(s, mech, repeats)).collect(),
        })
        .collect();

    // Analytical prefilter: price every job of every variant in closed
    // form (microseconds per point), keep only the predicted frontier
    // for simulation. Pruned variants still appear in the merged
    // document with their predicted stats.
    let (ranked, confirmed) = if prefilter_on {
        // Predictions are content-addressed in the same cache as
        // simulated outcomes (disjoint key space), so re-ranking an
        // unchanged grid under --cache re-prices nothing.
        let ranked = prefilter::rank_cached(&grid, sweep_opts.csr_latency, cache.as_ref());
        if let Some(cache) = &cache {
            eprintln!(
                "prefilter: prediction cache {} hit(s), {} miss(es)",
                cache.prediction_hits(),
                cache.prediction_misses()
            );
        }
        let k = prefilter::confirm_count(grid.len(), confirm_top, confirm_frac);
        let keep = prefilter::frontier(&ranked, k);
        let mut mask = vec![false; grid.len()];
        for &i in &keep {
            mask[i] = true;
        }
        eprintln!(
            "prefilter: analytical ranking confirms {}/{} variants: {}",
            keep.len(),
            grid.len(),
            keep.iter().map(|&i| grid[i].label.as_str()).collect::<Vec<_>>().join(", ")
        );
        (Some(ranked), mask)
    } else {
        (None, vec![true; grid.len()])
    };

    // One plan per confirmed variant, shared by every transport — the
    // merged document can only differ between transports if the
    // simulation does.
    let mut plans: Vec<(usize, SweepPlan)> = Vec::new();
    for (variant, gv) in grid.iter().enumerate() {
        if confirmed[variant] {
            plans.push((variant, SweepPlan::stride(&gv.cfg, gv.requests.clone(), sweep_opts)));
        }
    }

    let mut results: Vec<(usize, SweepResult)> = Vec::new();
    let mut reports: Vec<(usize, DispatchReport)> = Vec::new();
    // Variants are dispatched one plan at a time: retry/straggler
    // accounting and the dispatch report are per-plan, and per-variant
    // stats must stay separate for the merged document. The cost is a
    // capacity tail at each variant boundary (a slow last shard can
    // idle the other worker slots); the default shards-per-variant ==
    // processes plus stride partitioning keeps that tail one balanced
    // shard wide.
    let outcome: Result<()> = (|| {
        for (variant, plan) in plans {
            let prefix = format!("v{variant}_");
            let base: Box<dyn Transport> = match transport {
                "inprocess" => Box::new(InProcess),
                "subprocess" => Box::new(
                    Subprocess::new(&work_dir, &prefix, !ephemeral, cli_workers(args)?)
                        .map_err(|e| anyhow!(e))?,
                ),
                "spool" => {
                    let dir = args.get("spool").ok_or_else(|| {
                        anyhow!("--transport spool needs --spool DIR (a shared spool directory)")
                    })?;
                    Box::new(
                        SpoolDir::new(Path::new(dir), &prefix, spool_poll, spool_timeout)
                            .map_err(|e| anyhow!(e))?
                            // caching run: content-addressed offer
                            // stems, so a re-run of a killed sweep
                            // claims results already published into
                            // the spool instead of re-dispatching
                            // their shards
                            .with_resume(cache.is_some()),
                    )
                }
                other => bail!("unreachable transport {other:?}"),
            };
            // fault injection for the sched-smoke lane and manual retry
            // drills: fail the first dispatch of one shard of the first
            // variant, then behave normally
            let dispatchable: Box<dyn Transport> = match inject_fail {
                Some(idx) if variant == 0 => Box::new(FaultInjector::new(base, vec![idx], 1)),
                _ => base,
            };
            let dispatch_opts = DispatchOptions {
                max_retries: retries,
                straggler_factor,
                concurrency: match transport {
                    // every offer visible to remote executors at once
                    "spool" => plan.shards.len().max(1),
                    // the worker-process cap
                    "subprocess" => processes.max(1),
                    // in-process shards each own a thread pool already
                    _ => 1,
                },
                ..Default::default()
            };
            let (result, report) =
                dispatch_plan_cached(plan, &*dispatchable, &dispatch_opts, cache.as_ref())
                    .map_err(|e| anyhow!(e))?;
            eprintln!("variant {variant}: {}", report.summary());
            results.push((variant, result));
            reports.push((variant, report));
        }
        Ok(())
    })();
    if ephemeral && transport == "subprocess" {
        let _ = std::fs::remove_dir_all(&work_dir);
    }
    // Provenance is most valuable when the sweep FAILED, so the report
    // is written before the error propagates. It covers the variants
    // that completed; the failing variant's attempt chain travels in
    // the error message itself.
    if let Some(report_path) = args.get("report") {
        let doc = Json::Arr(
            reports
                .iter()
                .map(|(variant, report)| {
                    Json::obj(vec![
                        ("variant", Json::num(*variant as f64)),
                        ("dispatch", report.to_json()),
                    ])
                })
                .collect(),
        );
        std::fs::write(report_path, doc.pretty())?;
        eprintln!("wrote dispatch report {report_path}");
    }
    outcome?;

    let text = match &ranked {
        Some(ranked) => {
            sweep_doc_prefiltered(seed, workloads, repeats, ladder, ranked, &results).pretty()
        }
        None => {
            let variants: Vec<SweepVariantOutcome> = results
                .into_iter()
                .map(|(variant, result)| {
                    let (label, mechanisms, depth) = ladder[variant];
                    SweepVariantOutcome { label, depth, mechanisms, result }
                })
                .collect();
            sweep_doc(seed, workloads, repeats, &variants).pretty()
        }
    };
    match args.get("out") {
        Some(out) => {
            std::fs::write(out, text)?;
            eprintln!("wrote {out}");
        }
        None => println!("{text}"),
    }
    Ok(())
}

/// Parse `--seqs 64,128,256` (BERT sequence-length mix).
fn parse_seqs(args: &Args) -> Result<Vec<usize>> {
    match args.get("seqs") {
        None => Ok(WorkloadSpec::DEFAULT_SEQS.to_vec()),
        Some(list) => {
            let seqs: Vec<usize> = list
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse::<usize>()
                        .map_err(|_| anyhow!("--seqs: bad sequence length {s:?}"))
                })
                .collect::<Result<_>>()?;
            if seqs.is_empty() || seqs.contains(&0) {
                bail!("--seqs needs a non-empty list of positive lengths");
            }
            Ok(seqs)
        }
    }
}

/// Parse a comma-separated fault-injection flag (`--fail-device
/// 2@50000,3@90000`) through the given per-item parser.
fn parse_faults(
    args: &Args,
    key: &str,
    parse: fn(&str) -> std::result::Result<FaultSpec, String>,
) -> Result<Vec<FaultSpec>> {
    match args.get(key) {
        None => Ok(Vec::new()),
        Some(list) => list
            .split(',')
            .map(|item| parse(item.trim()).map_err(|e| anyhow!(e)))
            .collect(),
    }
}

/// A millisecond CLI knob: finite and non-negative, or a hard error.
fn nonneg_ms(args: &Args, key: &str, default: f64) -> Result<f64> {
    let v = args.f64_or(key, default)?;
    if !v.is_finite() || v < 0.0 {
        bail!("--{key} must be a non-negative duration in ms, got {v}");
    }
    Ok(v)
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let seqs = parse_seqs(args)?;
    let workload_name = args.get_or("workload", "bert");
    let workload = WorkloadSpec::from_name(workload_name, &seqs).ok_or_else(|| {
        anyhow!("--workload must be bert|bert-large|resnet18|mixed, got {workload_name:?}")
    })?;
    if args.has("seqs") && workload == WorkloadSpec::Resnet18 {
        // refuse rather than silently drop the operator's knob
        bail!("--seqs only applies to BERT workloads, not --workload {workload_name}");
    }
    let arrival = match args.get_or("arrival", "poisson") {
        "poisson" => ArrivalSpec::OpenPoisson { rate_rps: args.f64_or("rate", 200.0)? },
        "closed" => ArrivalSpec::ClosedLoop {
            clients: args.usize_or("clients", 4)?,
            think_cycles: ms_to_cycles(nonneg_ms(args, "think-ms", 0.0)?, cfg.freq_mhz),
        },
        other => bail!("--arrival must be poisson|closed, got {other:?}"),
    };
    let batching = match args.get_or("batching", "immediate") {
        "immediate" => BatchPolicy::Immediate,
        "size" => BatchPolicy::Size(args.usize_or("batch", 4)?),
        "deadline" => BatchPolicy::Deadline {
            max_batch: args.usize_or("batch", 4)?,
            max_wait_cycles: ms_to_cycles(nonneg_ms(args, "deadline-ms", 1.0)?, cfg.freq_mhz),
        },
        other => bail!("--batching must be immediate|size|deadline, got {other:?}"),
    };
    let placement_name = args.get_or("placement", "round-robin");
    let placement = PlacementPolicy::from_name(placement_name).ok_or_else(|| {
        anyhow!("--placement must be {}, got {placement_name:?}", PlacementPolicy::VALID_NAMES)
    })?;
    let devices = args.usize_or("devices", 1)?;
    if devices == 0 {
        bail!("--devices needs at least 1 device");
    }
    let mut faults = parse_faults(args, "fail-device", FaultSpec::parse_fail)?;
    faults.extend(parse_faults(args, "degrade-device", FaultSpec::parse_degrade)?);
    let slo_ms = match args.get("slo-ms") {
        Some(_) => Some(nonneg_ms(args, "slo-ms", 0.0)?),
        None => None,
    };
    let opts = ServeOptions {
        workload,
        arrival,
        batching,
        requests: args.usize_or("requests", 64)?,
        seed: args.u64_or("seed", 1)?,
        workers: args.usize_or("workers", 0)?,
        fast_forward: args.enabled_unless_no("fast-forward"),
        repeat_cap: args.usize_or("repeat-cap", 16)? as u32,
        dispatch_overhead_cycles: args.u64_or("overhead-cycles", 0)?,
        devices,
        placement,
        faults,
        slo_ms,
        hedge: args.has("hedge"),
        retries: args.usize_or("retries", 2)?,
        cache_dir: args.get("cache").map(PathBuf::from),
        cache_verify: args.has("cache-verify"),
    };
    let report = run_serve(&cfg, &opts).map_err(|e| anyhow!(e))?;
    let json = report.to_json().pretty();
    if args.has("json") {
        println!("{json}");
    } else {
        println!("{}", report.render());
    }
    if let Some(out) = args.get("out") {
        std::fs::write(out, &json)?;
        eprintln!("wrote {out}");
    }
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Runtime::default_dir);
    let mut rt = Runtime::load(&dir)?;
    let coord =
        Coordinator::new(cfg.clone()).with_fast_forward(args.enabled_unless_no("fast-forward"));
    let mut rng = Pcg32::seeded(args.u64_or("seed", 7)?);
    let mut checked = 0;
    for name in rt.artifact_names().iter().map(|s| s.to_string()).collect::<Vec<_>>() {
        if !name.starts_with("gemm_") {
            continue;
        }
        let meta = rt.meta(&name).unwrap().clone();
        let (m, k) = (meta.args[0].shape[0], meta.args[0].shape[1]);
        let n = meta.args[1].shape[1];
        let mut a = vec![0i8; m * k];
        let mut b = vec![0i8; k * n];
        rng.fill_i8(&mut a);
        rng.fill_i8(&mut b);
        let golden = rt.execute_gemm(&name, &a, &b)?;
        let req = JobRequest {
            shape: GemmShape::new(m, k, n),
            layout: Layout::TiledInterleaved,
            mechanisms: Mechanisms::ALL,
            repeats: 1,
            operands: Some((a, b)),
        };
        let sim = coord.run_one(&req).map_err(|e| anyhow!(e))?;
        let c = sim.c.expect("functional result");
        if c != golden {
            bail!("MISMATCH on {name}: simulator != AOT golden model");
        }
        println!("  {name:<24} ({m} x {k} x {n})  OK — bit-exact");
        checked += 1;
    }
    println!("verified {checked} GeMM artifacts: simulator == JAX/Pallas golden model");
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let model = PowerModel::default();
    println!("OpenGeMM platform instance");
    println!("  core     (Mu, Nu, Ku) = ({}, {}, {})", cfg.core.mu, cfg.core.nu, cfg.core.ku);
    println!(
        "  precision A/B/C       = {}/{}/{} bit",
        cfg.core.pa_bits, cfg.core.pb_bits, cfg.core.pc_bits
    );
    println!("  SPM      {} banks x {} x {}B = {} KiB",
        cfg.mem.n_bank, cfg.mem.d_mem, cfg.mem.word_bytes(),
        cfg.mem.capacity_bytes() / 1024);
    println!("  ports    R {} / W {}  buffers depth {}", cfg.mem.r_mem, cfg.mem.w_mem, cfg.mem.d_stream);
    println!("  clock    {} MHz", cfg.freq_mhz);
    println!("  peak     {:.1} GOPS", cfg.peak_gops());
    println!("  area     {:.3} mm^2 cell / {:.3} mm^2 layout (modeled)",
        model.total_area(&cfg), model.layout_area(&cfg));
    println!("  power    {:.1} mW @ full load -> {:.2} TOPS/W",
        model.total_power(&cfg, 1.0), model.tops_per_watt(&cfg, 1.0));
    Ok(())
}

/// Every in-repo experiment workload as a named lint target: `(target
/// name, platform config, job requests)`. These are the exact grids
/// the experiment drivers dispatch — same variant configs, shapes,
/// layouts, and repeat policies — so a clean `opengemm lint` means no
/// in-repo run can trip the admission gate.
fn lint_targets(
    cfg: &PlatformConfig,
    args: &Args,
) -> Result<Vec<(String, PlatformConfig, Vec<JobRequest>)>> {
    let seed = args.u64_or("seed", 2024)?;
    let workloads = args.usize_or("workloads", 40)?;
    let repeats = args.usize_or("repeats", 10)? as u32;
    let mut targets: Vec<(String, PlatformConfig, Vec<JobRequest>)> = Vec::new();

    // Fig. 5: every mechanism rung of the ablation ladder over the
    // seeded random suite (the sweep/ablation grid).
    let shapes = random_suite(seed, workloads);
    for &(label, mech, depth) in variant_specs().iter() {
        let requests = shapes.iter().map(|&s| JobRequest::timing(s, mech, repeats)).collect();
        targets.push((format!("fig5:{label}"), variant_config(cfg, depth), requests));
    }

    // Table 2: the DNN model streams, folded to unique shapes with the
    // driver's repeat clamp.
    let bert_seq = args.usize_or("bert-seq", 512)?;
    let max_repeats = args.usize_or("max-repeats", 10)? as u32;
    let models = [
        mobilenet_v2(),
        mobilenet_v2_host_dw(),
        resnet18(),
        vit_b16(),
        bert_base(bert_seq),
    ];
    for model in models {
        let requests = model
            .unique_shapes()
            .iter()
            .map(|&(shape, count)| {
                JobRequest::timing(shape, Mechanisms::ALL, (count as u32).clamp(1, max_repeats))
            })
            .collect();
        targets.push((format!("table2:{}", model.name), cfg.clone(), requests));
    }

    // Fig. 7: the square Gemmini-comparison sizes.
    let fig7_requests = opengemm::experiments::fig7::SIZES
        .iter()
        .map(|&d| JobRequest::timing(GemmShape::new(d, d, d), Mechanisms::ALL, repeats))
        .collect();
    targets.push(("fig7:sizes".to_string(), cfg.clone(), fig7_requests));

    // Serve: every workload's request-kind streams, at the repeat
    // points the service model actually measures (exact count up to
    // the default cap, else {1, cap} for extrapolation).
    let seqs = parse_seqs(args)?;
    let repeat_cap = args.usize_or("repeat-cap", 16)? as u64;
    for name in ["bert", "bert-large", "resnet18", "mixed"] {
        let spec = WorkloadSpec::from_name(name, &seqs).expect("built-in workload name");
        let mut points = std::collections::BTreeSet::new();
        for kind in spec.kinds() {
            for (shape, count) in kind.stream {
                if count <= repeat_cap {
                    points.insert((shape.m, shape.k, shape.n, count.max(1) as u32));
                } else {
                    points.insert((shape.m, shape.k, shape.n, 1));
                    points.insert((shape.m, shape.k, shape.n, repeat_cap.max(1) as u32));
                }
            }
        }
        let requests = points
            .into_iter()
            .map(|(m, k, n, r)| JobRequest::timing(GemmShape::new(m, k, n), Mechanisms::ALL, r))
            .collect();
        targets.push((format!("serve:{name}"), cfg.clone(), requests));
    }
    Ok(targets)
}

/// `opengemm lint`: run the static verifier over every experiment
/// workload grid (or `--target SUBSTR` to filter), print the human
/// table or the deterministic `opengemm-lint-report-v1` JSON, and exit
/// non-zero iff any target carries error-severity diagnostics.
fn cmd_lint(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let filter = args.get("target");
    let mut target_reports = Vec::new();
    for (name, tcfg, requests) in lint_targets(&cfg, args)? {
        if let Some(f) = filter {
            if !name.contains(f) {
                continue;
            }
        }
        let mut diagnostics = Vec::new();
        for (i, request) in requests.iter().enumerate() {
            let mut diags = analysis::verify_request(&tcfg, request);
            let s = request.shape;
            for d in &mut diags {
                d.message = format!("job {i} ({}x{}x{}): {}", s.m, s.k, s.n, d.message);
            }
            diagnostics.extend(diags);
        }
        analysis::sort_diagnostics(&mut diagnostics);
        target_reports.push(TargetReport { name, jobs: requests.len(), diagnostics });
    }
    if target_reports.is_empty() {
        bail!("--target {:?} matches no lint target", filter.unwrap_or(""));
    }
    let report = LintReport { targets: target_reports };
    let json = report.to_json().pretty();
    if args.has("json") {
        println!("{json}");
    } else {
        println!("{}", report.render());
    }
    if let Some(out) = args.get("out") {
        std::fs::write(out, &json)?;
        eprintln!("wrote {out}");
    }
    if report.has_errors() {
        bail!(
            "lint: {} error-severity diagnostic(s) across {} job(s)",
            report.count(Severity::Error),
            report.jobs()
        );
    }
    Ok(())
}

fn maybe_write(args: &Args, name: &str, content: &str) -> Result<()> {
    if let Some(dir) = args.get("out-dir") {
        std::fs::create_dir_all(dir)?;
        let path = std::path::Path::new(dir).join(format!("{name}.md"));
        std::fs::write(&path, content)?;
        eprintln!("wrote {path:?}");
    }
    Ok(())
}

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    let sub = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    let result = match sub {
        "simulate" => cmd_simulate(&args),
        "ablation" => cmd_ablation(&args),
        "dnn" => cmd_dnn(&args),
        "area-power" => cmd_area_power(&args),
        "sota" => cmd_sota(&args),
        "compare-gemmini" => cmd_compare_gemmini(&args),
        "sweep" => cmd_sweep(&args),
        "lint" => cmd_lint(&args),
        "serve" => cmd_serve(&args),
        "verify" => cmd_verify(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand {other:?}\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
