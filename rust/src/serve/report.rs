//! Serving report: percentile tables + JSON for trend tracking.
//!
//! Deliberately free of wall-clock, host or worker-count fields: every
//! number is a deterministic function of (config, options, seed), so
//! two runs with the same seed serialize **byte-identically** — the
//! property the `serve-smoke` CI lane diffs, and what makes these
//! reports usable as regression baselines. The JSON shares `util::json`
//! with the sweep wire format, so trend tooling can ingest both.

use crate::coordinator::CoordinatorStats;
use crate::util::json::Json;
use crate::util::stats::TailSummary;
use crate::util::table::{fmt_f, Table};

use super::arrival::ArrivalSpec;
use super::batching::BatchPolicy;

/// Wire-format marker, so downstream tooling fed the wrong file fails
/// loudly.
pub const SERVE_REPORT_FORMAT: &str = "opengemm-serve-report-v1";

/// Per-request-kind serving outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct KindSummary {
    pub label: String,
    /// Requests of this kind served.
    pub served: usize,
    /// Stream cost of one request of this kind, in device cycles.
    pub service_cycles: u64,
}

/// The complete serving-harness result.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeReport {
    pub workload: Json,
    pub arrival: ArrivalSpec,
    pub batching: BatchPolicy,
    pub seed: u64,
    pub freq_mhz: u64,
    /// Requests served (every scheduled request completes).
    pub requests: usize,
    pub batches: usize,
    /// Makespan: cycle of the last batch completion (0 when idle).
    pub duration_cycles: u64,
    /// Cycles the device spent serving batches (overhead included).
    pub device_busy_cycles: u64,
    /// `None` when the window served no requests — an idle window is a
    /// legitimate outcome, not a panic (see `util::stats`).
    pub latency_ms: Option<TailSummary>,
    pub queueing_ms: Option<TailSummary>,
    pub service_ms: Option<TailSummary>,
    pub kinds: Vec<KindSummary>,
    /// Measurement-side simulation counters (deterministic: the set of
    /// measured jobs and their cycle counts depend only on the
    /// workload, not on pool size or timing).
    pub measurement: CoordinatorStats,
}

impl ServeReport {
    /// Completed requests per second of virtual device time.
    pub fn throughput_rps(&self) -> f64 {
        if self.duration_cycles == 0 {
            return 0.0;
        }
        self.requests as f64 * self.freq_mhz as f64 * 1e6 / self.duration_cycles as f64
    }

    /// Fraction of the makespan the device was serving.
    pub fn device_utilization(&self) -> f64 {
        if self.duration_cycles == 0 {
            return 0.0;
        }
        self.device_busy_cycles as f64 / self.duration_cycles as f64
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.requests as f64 / self.batches as f64
    }

    /// Wire encoding.
    pub fn to_json(&self) -> Json {
        let tail = |t: &Option<TailSummary>| match t {
            Some(t) => t.to_json(),
            None => Json::Null,
        };
        let kinds: Vec<Json> = self
            .kinds
            .iter()
            .map(|k| {
                Json::obj(vec![
                    ("label", Json::str(k.label.clone())),
                    ("served", Json::num(k.served as f64)),
                    ("service_cycles", Json::num(k.service_cycles as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("format", Json::str(SERVE_REPORT_FORMAT)),
            ("workload", self.workload.clone()),
            ("arrival", self.arrival.to_json()),
            ("batching", self.batching.to_json()),
            ("seed", Json::num(self.seed as f64)),
            ("freq_mhz", Json::num(self.freq_mhz as f64)),
            ("requests", Json::num(self.requests as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("duration_cycles", Json::num(self.duration_cycles as f64)),
            ("device_busy_cycles", Json::num(self.device_busy_cycles as f64)),
            ("throughput_rps", Json::num(self.throughput_rps())),
            ("device_utilization", Json::num(self.device_utilization())),
            ("latency_ms", tail(&self.latency_ms)),
            ("queueing_ms", tail(&self.queueing_ms)),
            ("service_ms", tail(&self.service_ms)),
            ("kinds", Json::Arr(kinds)),
            ("measurement", self.measurement.to_json()),
        ])
    }

    /// Human-readable report: header lines + percentile table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("## Serving report\n\n");
        out.push_str(&format!(
            "workload {}  arrival {}  batching {}  seed {}\n",
            self.workload.get("name").and_then(|n| n.as_str()).unwrap_or("?"),
            self.arrival.label(),
            self.batching.label(),
            self.seed
        ));
        out.push_str(&format!(
            "{} requests in {} batches (mean size {:.2}), makespan {:.2} ms @ {} MHz\n",
            self.requests,
            self.batches,
            self.mean_batch_size(),
            self.duration_cycles as f64 / (self.freq_mhz as f64 * 1e3),
            self.freq_mhz
        ));
        out.push_str(&format!(
            "throughput {:.1} req/s, device utilization {:.1}%\n\n",
            self.throughput_rps(),
            100.0 * self.device_utilization()
        ));
        match (&self.latency_ms, &self.queueing_ms, &self.service_ms) {
            (Some(lat), Some(que), Some(srv)) => {
                let mut t =
                    Table::new(&["latency (ms)", "p50", "p90", "p95", "p99", "max", "mean"]);
                for (name, s) in [("end-to-end", lat), ("queueing", que), ("service", srv)] {
                    t.row(vec![
                        name.to_string(),
                        fmt_f(s.p50, 3),
                        fmt_f(s.p90, 3),
                        fmt_f(s.p95, 3),
                        fmt_f(s.p99, 3),
                        fmt_f(s.max, 3),
                        fmt_f(s.mean, 3),
                    ]);
                }
                out.push_str(&t.markdown());
            }
            _ => out.push_str("(no requests served in this window)\n"),
        }
        if !self.kinds.is_empty() {
            out.push('\n');
            let mut t = Table::new(&["request kind", "served", "service ms/req"]);
            for k in &self.kinds {
                t.row(vec![
                    k.label.clone(),
                    k.served.to_string(),
                    fmt_f(k.service_cycles as f64 / (self.freq_mhz as f64 * 1e3), 3),
                ]);
            }
            out.push_str(&t.markdown());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn report(requests: usize) -> ServeReport {
        let samples: Vec<f64> = (0..requests).map(|i| i as f64 + 1.0).collect();
        let tail = TailSummary::compute(&samples);
        ServeReport {
            workload: Json::obj(vec![("name", Json::str("bert"))]),
            arrival: ArrivalSpec::OpenPoisson { rate_rps: 100.0 },
            batching: BatchPolicy::Immediate,
            seed: 7,
            freq_mhz: 200,
            requests,
            batches: requests,
            duration_cycles: requests as u64 * 1000,
            device_busy_cycles: requests as u64 * 900,
            latency_ms: tail.clone(),
            queueing_ms: tail.clone(),
            service_ms: tail,
            kinds: vec![KindSummary {
                label: "bert-base-layer/seq64".into(),
                served: requests,
                service_cycles: 900,
            }],
            measurement: CoordinatorStats::default(),
        }
    }

    #[test]
    fn json_roundtrips_and_has_percentiles() {
        let r = report(10);
        let text = r.to_json().pretty();
        let back = json::parse(&text).unwrap();
        assert_eq!(back.pretty(), text, "stable serialization");
        assert!(text.contains("\"p99\"") && text.contains(SERVE_REPORT_FORMAT));
    }

    #[test]
    fn empty_window_is_null_not_panic() {
        let r = report(0);
        assert_eq!(r.latency_ms, None);
        assert_eq!(r.throughput_rps(), 0.0);
        assert_eq!(r.device_utilization(), 0.0);
        assert_eq!(r.mean_batch_size(), 0.0);
        let text = r.to_json().pretty();
        assert!(text.contains("\"latency_ms\": null"));
        assert!(r.render().contains("no requests served"));
    }

    #[test]
    fn render_mentions_all_percentile_columns() {
        let text = report(5).render();
        for col in ["p50", "p90", "p95", "p99", "end-to-end", "queueing", "service"] {
            assert!(text.contains(col), "missing {col}");
        }
    }
}
