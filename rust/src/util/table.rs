//! ASCII/markdown table rendering for regenerated paper tables and
//! figure data series.

/// A simple column-aligned table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// Render as a GitHub-flavoured markdown table.
    pub fn markdown(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| {
            let mut line = String::from("|");
            for (c, width) in cells.iter().zip(w) {
                line.push_str(&format!(" {:<width$} |", c, width = width));
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &w));
        out.push('\n');
        out.push('|');
        for width in &w {
            out.push_str(&format!("{:-<w$}|", "", w = width + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &w));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (no quoting needed for our numeric tables; commas in
    /// cells are replaced by semicolons defensively).
    pub fn csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| s.replace(',', ";");
        out.push_str(
            &self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with a fixed number of decimals, trimming noise.
pub fn fmt_f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

/// Format a large count in scientific-ish notation like the paper's
/// "3.33 x 10^8" cycle counts.
pub fn fmt_sci(v: f64) -> String {
    if v == 0.0 {
        return "0".into();
    }
    let exp = v.abs().log10().floor() as i32;
    let mant = v / 10f64.powi(exp);
    format!("{mant:.2}e{exp}")
}

/// Render a horizontal ASCII box plot row on a [lo, hi] axis of `width`
/// characters (used by the Fig. 5 report).
pub fn ascii_box(
    lo: f64,
    hi: f64,
    width: usize,
    min: f64,
    q1: f64,
    med: f64,
    q3: f64,
    max: f64,
) -> String {
    assert!(hi > lo && width >= 10);
    let clamp_pos = |v: f64| -> usize {
        let t = ((v - lo) / (hi - lo)).clamp(0.0, 1.0);
        ((width - 1) as f64 * t).round() as usize
    };
    let (pmin, pq1, pmed, pq3, pmax) =
        (clamp_pos(min), clamp_pos(q1), clamp_pos(med), clamp_pos(q3), clamp_pos(max));
    let mut chars = vec![' '; width];
    for c in chars.iter_mut().take(pq1).skip(pmin) {
        *c = '-';
    }
    for c in chars.iter_mut().take(pmax + 1).skip(pq3 + 1) {
        *c = '-';
    }
    for c in chars.iter_mut().take(pq3 + 1).skip(pq1) {
        *c = '=';
    }
    chars[pq1] = '[';
    chars[pq3] = ']';
    chars[pmed] = '|';
    chars.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_has_header_and_rows() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.markdown();
        assert!(md.contains("| a | bb |"));
        assert!(md.contains("| 1 | 2  |"));
        assert!(md.lines().nth(1).unwrap().starts_with("|-"));
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        Table::new(&["a"]).row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn csv_roundtrip() {
        let mut t = Table::new(&["x", "y"]);
        t.row(vec!["1".into(), "2,5".into()]);
        let csv = t.csv();
        assert_eq!(csv, "x,y\n1,2;5\n");
    }

    #[test]
    fn sci_format() {
        assert_eq!(fmt_sci(3.33e8), "3.33e8");
        assert_eq!(fmt_sci(0.0), "0");
        assert_eq!(fmt_sci(204.8), "2.05e2");
    }

    #[test]
    fn box_plot_markers_ordered() {
        let s = ascii_box(0.0, 1.0, 40, 0.1, 0.3, 0.5, 0.7, 0.9);
        let i1 = s.find('[').unwrap();
        let im = s.find('|').unwrap();
        let i3 = s.find(']').unwrap();
        assert!(i1 < im && im < i3);
        assert_eq!(s.len(), 40);
    }
}
