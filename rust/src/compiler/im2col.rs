//! Convolution-as-GeMM translation (Sec. 2.3 and [21]).
//!
//! A convolution with input `(N, H, W, C)` (NHWC) and kernel
//! `(KH, KW, C, K)` lowers to a GeMM with
//! `A: (N*OH*OW, KH*KW*C)` and `B: (KH*KW*C, K)` — the paper's
//! `(Ox*Oy, Fx*Fy*C) x (Fx*Fy*C, K)` formulation. Grouped/depthwise
//! convolutions lower to `groups` independent GeMMs with `C/groups`
//! channels each (for depthwise: K' = 1, the "thin channel" case the
//! paper blames for MobileNetV2's lower utilization).

use super::tiling::GemmShape;

/// A convolution layer shape (VALID padding handled by pre-padded H/W;
/// `pad` is applied symmetrically before the window walk).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvShape {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub kh: usize,
    pub kw: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    /// Channel groups (1 = dense conv, `c` = depthwise).
    pub groups: usize,
}

impl ConvShape {
    pub fn dense(n: usize, h: usize, w: usize, c: usize, kh: usize, kw: usize, k: usize, stride: usize, pad: usize) -> ConvShape {
        ConvShape { n, h, w, c, kh, kw, k, stride, pad, groups: 1 }
    }

    pub fn depthwise(n: usize, h: usize, w: usize, c: usize, kh: usize, kw: usize, stride: usize, pad: usize) -> ConvShape {
        ConvShape { n, h, w, c, kh, kw, k: c, stride, pad, groups: c }
    }

    pub fn out_h(&self) -> usize {
        (self.h + 2 * self.pad - self.kh) / self.stride + 1
    }

    pub fn out_w(&self) -> usize {
        (self.w + 2 * self.pad - self.kw) / self.stride + 1
    }

    /// The GeMM shape of ONE group after im2col.
    pub fn gemm_shape(&self) -> GemmShape {
        assert_eq!(self.c % self.groups, 0, "channels not divisible by groups");
        assert_eq!(self.k % self.groups, 0, "filters not divisible by groups");
        let cg = self.c / self.groups;
        let kg = self.k / self.groups;
        GemmShape::new(
            self.n * self.out_h() * self.out_w(),
            self.kh * self.kw * cg,
            kg,
        )
    }

    /// Number of identical GeMMs this conv lowers to (= groups).
    pub fn gemm_count(&self) -> usize {
        self.groups
    }

    /// Real MACs of the full convolution.
    pub fn macs(&self) -> u64 {
        self.gemm_shape().macs() * self.groups as u64
    }
}

/// Functional im2col for one group of an NHWC int8 tensor: returns the
/// `(N*OH*OW) x (KH*KW*Cg)` A-matrix, feature order (kh, kw, c) — the
/// same order as the Python oracle (`im2col_ref`) and the weight
/// reshape `w.reshape(KH*KW*C, K)`.
pub fn im2col(x: &[i8], s: &ConvShape, group: usize) -> Vec<i8> {
    let cg = s.c / s.groups;
    let c_lo = group * cg;
    assert_eq!(x.len(), s.n * s.h * s.w * s.c, "input size mismatch");
    let (oh, ow) = (s.out_h(), s.out_w());
    let mut out = vec![0i8; s.n * oh * ow * s.kh * s.kw * cg];
    let mut row = 0usize;
    for n in 0..s.n {
        for oy in 0..oh {
            for ox in 0..ow {
                let base = row * (s.kh * s.kw * cg);
                for ky in 0..s.kh {
                    let iy = (oy * s.stride + ky) as isize - s.pad as isize;
                    for kx in 0..s.kw {
                        let ix = (ox * s.stride + kx) as isize - s.pad as isize;
                        for ci in 0..cg {
                            let dst = base + (ky * s.kw + kx) * cg + ci;
                            if iy >= 0 && (iy as usize) < s.h && ix >= 0 && (ix as usize) < s.w {
                                let src = ((n * s.h + iy as usize) * s.w + ix as usize) * s.c
                                    + c_lo
                                    + ci;
                                out[dst] = x[src];
                            } // else zero padding
                        }
                    }
                }
                row += 1;
            }
        }
    }
    out
}

/// Reshape a `(KH, KW, C, K)` weight tensor into the B-matrix
/// `(KH*KW*Cg, Kg)` of one group.
pub fn weights_to_b(w: &[i8], s: &ConvShape, group: usize) -> Vec<i8> {
    let cg = s.c / s.groups;
    let kg = s.k / s.groups;
    assert_eq!(w.len(), s.kh * s.kw * s.c * s.k, "weight size mismatch");
    let mut out = vec![0i8; s.kh * s.kw * cg * kg];
    for ky in 0..s.kh {
        for kx in 0..s.kw {
            for ci in 0..cg {
                for ko in 0..kg {
                    let src = ((ky * s.kw + kx) * s.c + group * cg + ci) * s.k + group * kg + ko;
                    let dst = ((ky * s.kw + kx) * cg + ci) * kg + ko;
                    out[dst] = w[src];
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_dims() {
        let s = ConvShape::dense(1, 224, 224, 3, 7, 7, 64, 2, 3);
        assert_eq!(s.out_h(), 112);
        let g = s.gemm_shape();
        assert_eq!((g.m, g.k, g.n), (112 * 112, 147, 64));
    }

    #[test]
    fn depthwise_lowering() {
        let s = ConvShape::depthwise(1, 56, 56, 32, 3, 3, 1, 1);
        assert_eq!(s.gemm_count(), 32);
        let g = s.gemm_shape();
        assert_eq!((g.m, g.k, g.n), (56 * 56, 9, 1));
        assert_eq!(s.macs(), 56 * 56 * 9 * 32);
    }

    #[test]
    fn im2col_matches_direct_conv() {
        // tiny conv, brute-force reference
        let s = ConvShape::dense(1, 5, 5, 2, 3, 3, 4, 1, 0);
        let x: Vec<i8> = (0..5 * 5 * 2).map(|i| (i as i8).wrapping_mul(3)).collect();
        let w: Vec<i8> = (0..3 * 3 * 2 * 4).map(|i| (i as i8).wrapping_sub(20)).collect();
        let a = im2col(&x, &s, 0);
        let b = weights_to_b(&w, &s, 0);
        let g = s.gemm_shape();
        // GeMM
        let mut c = vec![0i64; g.m * g.n];
        for i in 0..g.m {
            for j in 0..g.n {
                for kk in 0..g.k {
                    c[i * g.n + j] += a[i * g.k + kk] as i64 * b[kk * g.n + j] as i64;
                }
            }
        }
        // direct convolution
        for oy in 0..3usize {
            for ox in 0..3usize {
                for ko in 0..4usize {
                    let mut acc = 0i64;
                    for ky in 0..3 {
                        for kx in 0..3 {
                            for ci in 0..2 {
                                let xv = x[((oy + ky) * 5 + (ox + kx)) * 2 + ci] as i64;
                                let wv = w[((ky * 3 + kx) * 2 + ci) * 4 + ko] as i64;
                                acc += xv * wv;
                            }
                        }
                    }
                    assert_eq!(c[(oy * 3 + ox) * 4 + ko], acc, "at ({oy},{ox},{ko})");
                }
            }
        }
    }

    #[test]
    fn padding_zeroes_outside() {
        let s = ConvShape::dense(1, 3, 3, 1, 3, 3, 1, 1, 1);
        let x = vec![1i8; 9];
        let a = im2col(&x, &s, 0);
        assert_eq!(s.out_h(), 3);
        // corner output (0,0): 4 taps inside, 5 outside
        let first_row = &a[0..9];
        let inside: i32 = first_row.iter().map(|&v| v as i32).sum();
        assert_eq!(inside, 4);
    }

    #[test]
    fn grouped_conv_partitions_channels() {
        let mut s = ConvShape::dense(1, 4, 4, 4, 1, 1, 4, 1, 0);
        s.groups = 2;
        let x: Vec<i8> = (0..4 * 4 * 4).map(|i| i as i8).collect();
        let a0 = im2col(&x, &s, 0);
        let a1 = im2col(&x, &s, 1);
        // group 0 sees channels 0..2, group 1 sees channels 2..4
        assert_eq!(a0[0], x[0]);
        assert_eq!(a0[1], x[1]);
        assert_eq!(a1[0], x[2]);
        assert_eq!(a1[1], x[3]);
        let g = s.gemm_shape();
        assert_eq!((g.m, g.k, g.n), (16, 2, 2));
    }
}
