//! Serving-style driver: push a batch of BERT-Base encoder "requests"
//! through the coordinator (each request = the GeMM stream of one
//! encoder layer at a given sequence length) and report latency and
//! throughput percentiles — the platform acting as an edge inference
//! service.
//!
//! Run with:  cargo run --release --example bert_serving [--requests N]

use std::time::Instant;

use opengemm::compiler::GemmShape;
use opengemm::config::{Mechanisms, PlatformConfig};
use opengemm::coordinator::{Coordinator, JobRequest};
use opengemm::util::cli::Args;
use opengemm::util::rng::Pcg32;
use opengemm::util::stats::BoxStats;

/// The GeMMs of one BERT-Base encoder layer at sequence length `s`.
fn encoder_layer_gemms(s: usize) -> Vec<(GemmShape, u64)> {
    let (d, h, dh, ffn) = (768usize, 12u64, 64usize, 3072usize);
    vec![
        (GemmShape::new(s, d, 3 * d), 1),   // qkv projection
        (GemmShape::new(s, dh, s), h),      // attention scores (per head)
        (GemmShape::new(s, s, dh), h),      // attention context (per head)
        (GemmShape::new(s, d, d), 1),       // output projection
        (GemmShape::new(s, d, ffn), 1),     // ffn up
        (GemmShape::new(s, ffn, d), 1),     // ffn down
    ]
}

fn main() -> opengemm::util::error::Result<()> {
    let args = Args::from_env()?;
    let n_requests = args.usize_or("requests", 32)?;
    let cfg = PlatformConfig::case_study();
    let coord =
        Coordinator::new(cfg.clone()).with_fast_forward(args.enabled_unless_no("fast-forward"));
    let mut rng = Pcg32::seeded(args.u64_or("seed", 1)?);

    // requests with mixed sequence lengths, like a real serving queue
    let seq_choices = [64usize, 128, 256, 384, 512];
    let requests: Vec<usize> =
        (0..n_requests).map(|_| *rng.choose(&seq_choices)).collect();

    println!("serving {n_requests} encoder-layer requests (seq in {seq_choices:?}) ...");
    let t0 = Instant::now();

    // fan each request's GeMMs out over the worker pool
    let mut latencies_ms = Vec::with_capacity(n_requests);
    let mut total_macs = 0u64;
    for &seq in &requests {
        let gemms = encoder_layer_gemms(seq);
        let repeats: Vec<u32> = gemms.iter().map(|&(_, c)| (c as u32).clamp(1, 12)).collect();
        let jobs: Vec<JobRequest> = gemms
            .iter()
            .zip(&repeats)
            .map(|(&(shape, _), &r)| JobRequest::timing(shape, Mechanisms::ALL, r))
            .collect();
        let results = coord.run_batch(jobs);
        // request latency = sum of per-GeMM platform cycles (sequential
        // on one device), at the platform clock
        let mut cycles = 0f64;
        for (((shape, count), outcome), reps) in gemms.iter().zip(results).zip(&repeats) {
            let r = outcome.expect("job ok");
            cycles += r.metrics.total_cycles as f64 / *reps as f64 * *count as f64;
            total_macs += shape.macs() * count;
        }
        latencies_ms.push(cycles / (cfg.freq_mhz as f64 * 1e3));
    }
    let wall = t0.elapsed().as_secs_f64();

    let stats = BoxStats::compute(&latencies_ms);
    println!("\nper-request device latency (ms @ {} MHz):", cfg.freq_mhz);
    println!(
        "  p0 {:.2}  p25 {:.2}  p50 {:.2}  p75 {:.2}  p100 {:.2}",
        stats.min, stats.q1, stats.median, stats.q3, stats.max
    );
    let device_time_s: f64 = latencies_ms.iter().sum::<f64>() / 1e3;
    println!(
        "device throughput: {:.1} req/s sequential, {:.1} GMAC/s effective ({:.1}% of peak)",
        n_requests as f64 / device_time_s,
        total_macs as f64 / device_time_s / 1e9,
        100.0 * (total_macs as f64 / device_time_s)
            / (cfg.peak_gops() / 2.0 * 1e9)
    );
    println!(
        "simulation wall-clock: {wall:.1}s ({:.1} M simulated cycles/s across workers)",
        coord.stats().simulated_cycles as f64 / wall / 1e6
    );
    Ok(())
}
