//! RV32I + Zicsr instruction encoders and a small two-pass assembler.
//!
//! The compiler (`compiler/codegen.rs`) emits real RISC-V machine code
//! for the Snitch-class host; the assembler provides labels, `li`
//! expansion and call/ret pseudo-instructions. Encodings follow the
//! RISC-V unprivileged spec v20191213.

use std::collections::HashMap;

/// Register ABI names.
pub mod reg {
    pub const ZERO: u32 = 0;
    pub const RA: u32 = 1;
    pub const SP: u32 = 2;
    pub const T0: u32 = 5;
    pub const T1: u32 = 6;
    pub const T2: u32 = 7;
    pub const S0: u32 = 8;
    pub const S1: u32 = 9;
    pub const A0: u32 = 10;
    pub const A1: u32 = 11;
    pub const A2: u32 = 12;
    pub const A3: u32 = 13;
    pub const A4: u32 = 14;
    pub const A5: u32 = 15;
    pub const A6: u32 = 16;
    pub const A7: u32 = 17;
    pub const S2: u32 = 18;
    pub const S3: u32 = 19;
    pub const S4: u32 = 20;
    pub const S5: u32 = 21;
    pub const T3: u32 = 28;
    pub const T4: u32 = 29;
    pub const T5: u32 = 30;
    pub const T6: u32 = 31;
}

#[inline]
fn r_type(funct7: u32, rs2: u32, rs1: u32, funct3: u32, rd: u32, opcode: u32) -> u32 {
    (funct7 << 25) | (rs2 << 20) | (rs1 << 15) | (funct3 << 12) | (rd << 7) | opcode
}

#[inline]
fn i_type(imm: i32, rs1: u32, funct3: u32, rd: u32, opcode: u32) -> u32 {
    debug_assert!((-2048..=2047).contains(&imm), "I-imm out of range: {imm}");
    ((imm as u32 & 0xfff) << 20) | (rs1 << 15) | (funct3 << 12) | (rd << 7) | opcode
}

#[inline]
fn s_type(imm: i32, rs2: u32, rs1: u32, funct3: u32, opcode: u32) -> u32 {
    debug_assert!((-2048..=2047).contains(&imm), "S-imm out of range: {imm}");
    let imm = imm as u32 & 0xfff;
    ((imm >> 5) << 25) | (rs2 << 20) | (rs1 << 15) | (funct3 << 12) | ((imm & 0x1f) << 7) | opcode
}

#[inline]
fn b_type(imm: i32, rs2: u32, rs1: u32, funct3: u32) -> u32 {
    debug_assert!(imm % 2 == 0 && (-4096..=4094).contains(&imm), "B-imm: {imm}");
    let imm = imm as u32 & 0x1fff;
    (((imm >> 12) & 1) << 31)
        | (((imm >> 5) & 0x3f) << 25)
        | (rs2 << 20)
        | (rs1 << 15)
        | (funct3 << 12)
        | (((imm >> 1) & 0xf) << 8)
        | (((imm >> 11) & 1) << 7)
        | 0x63
}

#[inline]
fn j_type(imm: i32, rd: u32) -> u32 {
    debug_assert!(imm % 2 == 0 && (-(1 << 20)..(1 << 20)).contains(&imm), "J-imm: {imm}");
    let imm = imm as u32 & 0x1f_ffff;
    (((imm >> 20) & 1) << 31)
        | (((imm >> 1) & 0x3ff) << 21)
        | (((imm >> 11) & 1) << 20)
        | (((imm >> 12) & 0xff) << 12)
        | (rd << 7)
        | 0x6f
}

// Bare encoders ------------------------------------------------------------

pub fn lui(rd: u32, imm20: u32) -> u32 {
    (imm20 << 12) | (rd << 7) | 0x37
}
pub fn auipc(rd: u32, imm20: u32) -> u32 {
    (imm20 << 12) | (rd << 7) | 0x17
}
pub fn addi(rd: u32, rs1: u32, imm: i32) -> u32 {
    i_type(imm, rs1, 0x0, rd, 0x13)
}
pub fn slti(rd: u32, rs1: u32, imm: i32) -> u32 {
    i_type(imm, rs1, 0x2, rd, 0x13)
}
pub fn sltiu(rd: u32, rs1: u32, imm: i32) -> u32 {
    i_type(imm, rs1, 0x3, rd, 0x13)
}
pub fn xori(rd: u32, rs1: u32, imm: i32) -> u32 {
    i_type(imm, rs1, 0x4, rd, 0x13)
}
pub fn ori(rd: u32, rs1: u32, imm: i32) -> u32 {
    i_type(imm, rs1, 0x6, rd, 0x13)
}
pub fn andi(rd: u32, rs1: u32, imm: i32) -> u32 {
    i_type(imm, rs1, 0x7, rd, 0x13)
}
pub fn slli(rd: u32, rs1: u32, shamt: u32) -> u32 {
    i_type(shamt as i32, rs1, 0x1, rd, 0x13)
}
pub fn srli(rd: u32, rs1: u32, shamt: u32) -> u32 {
    i_type(shamt as i32, rs1, 0x5, rd, 0x13)
}
pub fn srai(rd: u32, rs1: u32, shamt: u32) -> u32 {
    i_type((shamt | 0x400) as i32, rs1, 0x5, rd, 0x13)
}
pub fn add(rd: u32, rs1: u32, rs2: u32) -> u32 {
    r_type(0x00, rs2, rs1, 0x0, rd, 0x33)
}
pub fn sub(rd: u32, rs1: u32, rs2: u32) -> u32 {
    r_type(0x20, rs2, rs1, 0x0, rd, 0x33)
}
pub fn sll(rd: u32, rs1: u32, rs2: u32) -> u32 {
    r_type(0x00, rs2, rs1, 0x1, rd, 0x33)
}
pub fn slt(rd: u32, rs1: u32, rs2: u32) -> u32 {
    r_type(0x00, rs2, rs1, 0x2, rd, 0x33)
}
pub fn sltu(rd: u32, rs1: u32, rs2: u32) -> u32 {
    r_type(0x00, rs2, rs1, 0x3, rd, 0x33)
}
pub fn xor(rd: u32, rs1: u32, rs2: u32) -> u32 {
    r_type(0x00, rs2, rs1, 0x4, rd, 0x33)
}
pub fn srl(rd: u32, rs1: u32, rs2: u32) -> u32 {
    r_type(0x00, rs2, rs1, 0x5, rd, 0x33)
}
pub fn sra(rd: u32, rs1: u32, rs2: u32) -> u32 {
    r_type(0x20, rs2, rs1, 0x5, rd, 0x33)
}
pub fn or(rd: u32, rs1: u32, rs2: u32) -> u32 {
    r_type(0x00, rs2, rs1, 0x6, rd, 0x33)
}
pub fn and(rd: u32, rs1: u32, rs2: u32) -> u32 {
    r_type(0x00, rs2, rs1, 0x7, rd, 0x33)
}
pub fn lw(rd: u32, rs1: u32, imm: i32) -> u32 {
    i_type(imm, rs1, 0x2, rd, 0x03)
}
pub fn lb(rd: u32, rs1: u32, imm: i32) -> u32 {
    i_type(imm, rs1, 0x0, rd, 0x03)
}
pub fn lbu(rd: u32, rs1: u32, imm: i32) -> u32 {
    i_type(imm, rs1, 0x4, rd, 0x03)
}
pub fn lh(rd: u32, rs1: u32, imm: i32) -> u32 {
    i_type(imm, rs1, 0x1, rd, 0x03)
}
pub fn lhu(rd: u32, rs1: u32, imm: i32) -> u32 {
    i_type(imm, rs1, 0x5, rd, 0x03)
}
pub fn sw(rs2: u32, rs1: u32, imm: i32) -> u32 {
    s_type(imm, rs2, rs1, 0x2, 0x23)
}
pub fn sb(rs2: u32, rs1: u32, imm: i32) -> u32 {
    s_type(imm, rs2, rs1, 0x0, 0x23)
}
pub fn sh(rs2: u32, rs1: u32, imm: i32) -> u32 {
    s_type(imm, rs2, rs1, 0x1, 0x23)
}
pub fn jal(rd: u32, offset: i32) -> u32 {
    j_type(offset, rd)
}
pub fn jalr(rd: u32, rs1: u32, imm: i32) -> u32 {
    i_type(imm, rs1, 0x0, rd, 0x67)
}
pub fn beq(rs1: u32, rs2: u32, offset: i32) -> u32 {
    b_type(offset, rs2, rs1, 0x0)
}
pub fn bne(rs1: u32, rs2: u32, offset: i32) -> u32 {
    b_type(offset, rs2, rs1, 0x1)
}
pub fn blt(rs1: u32, rs2: u32, offset: i32) -> u32 {
    b_type(offset, rs2, rs1, 0x4)
}
pub fn bge(rs1: u32, rs2: u32, offset: i32) -> u32 {
    b_type(offset, rs2, rs1, 0x5)
}
pub fn bltu(rs1: u32, rs2: u32, offset: i32) -> u32 {
    b_type(offset, rs2, rs1, 0x6)
}
pub fn bgeu(rs1: u32, rs2: u32, offset: i32) -> u32 {
    b_type(offset, rs2, rs1, 0x7)
}
pub fn csrrw(rd: u32, csr: u32, rs1: u32) -> u32 {
    ((csr & 0xfff) << 20) | (rs1 << 15) | (0x1 << 12) | (rd << 7) | 0x73
}
pub fn csrrs(rd: u32, csr: u32, rs1: u32) -> u32 {
    ((csr & 0xfff) << 20) | (rs1 << 15) | (0x2 << 12) | (rd << 7) | 0x73
}
pub fn csrrc(rd: u32, csr: u32, rs1: u32) -> u32 {
    ((csr & 0xfff) << 20) | (rs1 << 15) | (0x3 << 12) | (rd << 7) | 0x73
}
pub fn csrrwi(rd: u32, csr: u32, uimm5: u32) -> u32 {
    ((csr & 0xfff) << 20) | ((uimm5 & 0x1f) << 15) | (0x5 << 12) | (rd << 7) | 0x73
}
pub fn ebreak() -> u32 {
    0x0010_0073
}
pub fn ecall() -> u32 {
    0x0000_0073
}
pub fn nop() -> u32 {
    addi(0, 0, 0)
}

// Assembler ----------------------------------------------------------------

/// Pending label reference kind.
#[derive(Debug, Clone, Copy)]
enum Fixup {
    Branch { funct3: u32, rs1: u32, rs2: u32 },
    Jal { rd: u32 },
}

/// Two-pass assembler with labels.
#[derive(Debug, Default)]
pub struct Asm {
    words: Vec<u32>,
    labels: HashMap<String, usize>,
    fixups: Vec<(usize, String, Fixup)>,
}

impl Asm {
    pub fn new() -> Asm {
        Asm::default()
    }

    pub fn here(&self) -> usize {
        self.words.len()
    }

    pub fn emit(&mut self, word: u32) -> &mut Self {
        self.words.push(word);
        self
    }

    pub fn label(&mut self, name: &str) -> &mut Self {
        let prev = self.labels.insert(name.to_string(), self.words.len());
        assert!(prev.is_none(), "duplicate label {name:?}");
        self
    }

    /// Load a 32-bit immediate: expands to `lui+addi` (or a single
    /// `addi`/`lui` when possible) — this is exactly what the paper's
    /// "sequential programming of numerous CSRs" costs per value.
    pub fn li(&mut self, rd: u32, value: i32) -> &mut Self {
        if (-2048..=2047).contains(&value) {
            self.emit(addi(rd, reg::ZERO, value));
        } else {
            let hi = ((value as u32).wrapping_add(0x800)) >> 12;
            let lo = (value as u32 & 0xfff) as i32;
            let lo = if lo >= 2048 { lo - 4096 } else { lo };
            self.emit(lui(rd, hi));
            if lo != 0 {
                self.emit(addi(rd, rd, lo));
            }
        }
        self
    }

    pub fn branch(&mut self, funct3: u32, rs1: u32, rs2: u32, target: &str) -> &mut Self {
        self.fixups.push((
            self.words.len(),
            target.to_string(),
            Fixup::Branch { funct3, rs1, rs2 },
        ));
        self.emit(0) // placeholder
    }

    pub fn beq_to(&mut self, rs1: u32, rs2: u32, t: &str) -> &mut Self {
        self.branch(0x0, rs1, rs2, t)
    }
    pub fn bne_to(&mut self, rs1: u32, rs2: u32, t: &str) -> &mut Self {
        self.branch(0x1, rs1, rs2, t)
    }
    pub fn blt_to(&mut self, rs1: u32, rs2: u32, t: &str) -> &mut Self {
        self.branch(0x4, rs1, rs2, t)
    }
    pub fn bge_to(&mut self, rs1: u32, rs2: u32, t: &str) -> &mut Self {
        self.branch(0x5, rs1, rs2, t)
    }
    pub fn bltu_to(&mut self, rs1: u32, rs2: u32, t: &str) -> &mut Self {
        self.branch(0x6, rs1, rs2, t)
    }
    pub fn bgeu_to(&mut self, rs1: u32, rs2: u32, t: &str) -> &mut Self {
        self.branch(0x7, rs1, rs2, t)
    }

    /// Jump-and-link to a label (used for `call`).
    pub fn jal_to(&mut self, rd: u32, target: &str) -> &mut Self {
        self.fixups
            .push((self.words.len(), target.to_string(), Fixup::Jal { rd }));
        self.emit(0)
    }

    pub fn call(&mut self, target: &str) -> &mut Self {
        self.jal_to(reg::RA, target)
    }

    pub fn ret(&mut self) -> &mut Self {
        self.emit(jalr(reg::ZERO, reg::RA, 0))
    }

    /// Resolve labels and return the final machine code.
    pub fn assemble(mut self) -> Vec<u32> {
        for (at, target, fixup) in std::mem::take(&mut self.fixups) {
            let dest = *self
                .labels
                .get(&target)
                .unwrap_or_else(|| panic!("undefined label {target:?}"));
            let offset = (dest as i64 - at as i64) * 4;
            let offset = i32::try_from(offset).expect("branch offset overflow");
            self.words[at] = match fixup {
                Fixup::Branch { funct3, rs1, rs2 } => b_type(offset, rs2, rs1, funct3),
                Fixup::Jal { rd } => j_type(offset, rd),
            };
        }
        self.words
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Cross-checked against riscv64-unknown-elf-as output.
    #[test]
    fn known_encodings() {
        assert_eq!(addi(1, 0, 42), 0x02a0_0093); // addi x1, x0, 42
        assert_eq!(lui(5, 0x12345), 0x1234_52b7); // lui t0, 0x12345
        assert_eq!(add(3, 1, 2), 0x0020_81b3); // add x3, x1, x2
        assert_eq!(sub(3, 1, 2), 0x4020_81b3);
        assert_eq!(lw(10, 2, 8), 0x0081_2503); // lw a0, 8(sp)
        assert_eq!(sw(10, 2, 8), 0x00a1_2423); // sw a0, 8(sp)
        assert_eq!(jal(1, 8), 0x0080_00ef); // jal ra, +8
        assert_eq!(jalr(0, 1, 0), 0x0000_8067); // ret
        assert_eq!(beq(1, 2, 8), 0x0020_8463);
        assert_eq!(csrrw(0, 0x3c0, 5), 0x3c02_9073); // csrrw x0, 0x3c0, t0
        assert_eq!(csrrs(6, 0x3ce, 0), 0x3ce0_2373); // csrrs t1, 0x3ce, x0
        assert_eq!(ebreak(), 0x0010_0073);
        assert_eq!(srai(7, 7, 3), 0x4033_d393);
    }

    #[test]
    fn li_small_is_one_insn() {
        let mut a = Asm::new();
        a.li(5, 100);
        assert_eq!(a.assemble(), vec![addi(5, 0, 100)]);
    }

    #[test]
    fn li_large_is_lui_addi() {
        let mut a = Asm::new();
        a.li(5, 0x12345678);
        let words = a.assemble();
        assert_eq!(words.len(), 2);
        assert_eq!(words[0] & 0x7f, 0x37); // lui
        // Behavioural check happens in cpu.rs tests (executes li).
    }

    #[test]
    fn li_negative_low_carry() {
        // 0x12345FFF has low 12 bits >= 0x800 -> hi must be bumped
        let mut a = Asm::new();
        a.li(5, 0x12345fff_u32 as i32);
        let w = a.assemble();
        assert_eq!(w.len(), 2);
        assert_eq!(w[0], lui(5, 0x12346));
        assert_eq!(w[1], addi(5, 5, -1));
    }

    #[test]
    fn labels_resolve_backward_and_forward() {
        let mut a = Asm::new();
        a.label("start");
        a.li(5, 0);
        a.bne_to(5, 0, "end");
        a.beq_to(0, 0, "start");
        a.label("end");
        a.emit(ebreak());
        let words = a.assemble();
        assert_eq!(words.len(), 4);
        // backward branch offset is negative
        assert_eq!(words[2], beq(0, 0, -8));
        // forward branch offset: 2 instructions ahead
        assert_eq!(words[1], bne(5, 0, 8));
    }

    #[test]
    #[should_panic(expected = "undefined label")]
    fn undefined_label_panics() {
        let mut a = Asm::new();
        a.beq_to(0, 0, "nowhere");
        a.assemble();
    }

    #[test]
    #[should_panic(expected = "duplicate label")]
    fn duplicate_label_panics() {
        let mut a = Asm::new();
        a.label("x");
        a.label("x");
    }
}
