"""Pure-jnp correctness oracles for the Pallas kernels.

These are the golden numerics for the whole stack:

- the Pallas kernel (L1) is pytest-checked against these functions;
- the AOT artifacts lowered from the L2 model are executed from Rust via
  PJRT and cross-checked against the Rust simulator's functional datapath,
  which therefore transitively agrees with these oracles.

Everything here is exact integer arithmetic (INT8 x INT8 -> INT32), the
datapath of the paper's DotProd units (P_A = P_B = 8, P_C = 32).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gemm_int8_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Reference INT8 GeMM: C[M,N] = A[M,K] @ B[K,N], int32 accumulation.

    Matches the accelerator's output-stationary datapath exactly: products
    and partial sums are accumulated in 32-bit integers with wraparound
    semantics (the hardware has no saturation on the accumulate path).
    """
    if a.dtype != jnp.int8 or b.dtype != jnp.int8:
        raise TypeError(f"expected int8 operands, got {a.dtype} x {b.dtype}")
    return jax.lax.dot_general(
        a,
        b,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def requantize_ref(acc: jax.Array, shift: int, zero_point: int = 0) -> jax.Array:
    """Reference requantization: int32 accumulator -> int8 activation.

    Power-of-two scaling (add-half then arithmetic right shift, i.e.
    round-half-up in two's complement -- the cheap hardware rounding the
    SNAX/Gemmini-style integer requantizers use), then saturating cast.
    """
    if shift < 0 or shift > 31:
        raise ValueError(f"shift out of range: {shift}")
    if shift > 0:
        rounded = (acc + (1 << (shift - 1))) >> shift
    else:
        rounded = acc
    rounded = rounded + jnp.int32(zero_point)
    return jnp.clip(rounded, -128, 127).astype(jnp.int8)


def linear_ref(a: jax.Array, w: jax.Array, bias: jax.Array, shift: int) -> jax.Array:
    """Reference quantized linear layer: requant(A @ W + bias)."""
    acc = gemm_int8_ref(a, w) + bias.astype(jnp.int32)
    return requantize_ref(acc, shift)


def im2col_ref(x: jax.Array, kh: int, kw: int, stride: int = 1) -> jax.Array:
    """im2col for NHWC input (VALID padding).

    Returns a matrix of shape (N*OH*OW, KH*KW*C): each row is the receptive
    field of one output pixel, exactly the paper's A-matrix construction
    for convolution-as-GeMM (Sec. 2.3: A is (Ox*Oy, Fx*Fy*C)).
    """
    n, h, w, c = x.shape
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    patches = jax.lax.conv_general_dilated_patches(
        x.astype(jnp.float32),
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    # conv_general_dilated_patches returns features in (C, KH, KW) order on
    # the last axis; reorder to (KH, KW, C) to match the weight layout
    # w.reshape(KH*KW*C, K).
    patches = patches.reshape(n, oh, ow, c, kh, kw)
    patches = patches.transpose(0, 1, 2, 4, 5, 3)
    return patches.reshape(n * oh * ow, kh * kw * c).astype(x.dtype)


def conv2d_im2col_ref(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """Reference conv2d computed as im2col + INT8 GeMM.

    x: (N, H, W, C) int8, w: (KH, KW, C, K) int8 -> (N, OH, OW, K) int32.
    """
    n, h, wd, c = x.shape
    kh, kw, c2, k = w.shape
    assert c == c2, (c, c2)
    oh = (h - kh) // stride + 1
    ow = (wd - kw) // stride + 1
    a = im2col_ref(x, kh, kw, stride)  # (N*OH*OW, KH*KW*C)
    b = w.reshape(kh * kw * c, k)  # (KH*KW*C, K)
    out = gemm_int8_ref(a, b)
    return out.reshape(n, oh, ow, k)


def mha_scores_ref(q: jax.Array, k: jax.Array, shift: int) -> jax.Array:
    """Reference attention-score block: requant(Q @ K^T).

    q: (S, D) int8, k: (S, D) int8 -> (S, S) int8. The softmax itself runs
    on the host in the paper's platform (the accelerator only does GeMM),
    so the artifact boundary is the requantized score matrix.
    """
    acc = gemm_int8_ref(q, k.T)
    return requantize_ref(acc, shift)


def mlp_block_ref(
    x: jax.Array,
    w1: jax.Array,
    b1: jax.Array,
    w2: jax.Array,
    b2: jax.Array,
    shift1: int,
    shift2: int,
) -> jax.Array:
    """Reference transformer MLP block: linear -> ReLU -> linear (all int8)."""
    h = linear_ref(x, w1, b1, shift1)
    h = jnp.maximum(h, jnp.int8(0))
    return linear_ref(h, w2, b2, shift2)
