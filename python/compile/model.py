"""L2: JAX compute graphs that call the L1 Pallas kernels.

Each ``make_*`` factory returns ``(fn, example_specs)`` where ``fn`` is the
jit-lowerable computation and ``example_specs`` the argument
ShapeDtypeStructs. ``aot.py`` lowers these once to HLO text artifacts; the
Rust runtime loads and executes them via PJRT. All functions return tuples
(the Rust side unwraps with ``to_tuple1``/``to_tuple``).

These graphs are the *functional golden model* of the accelerator
platform: the Rust cycle-accurate simulator's datapath must agree
bit-exactly with them (see rust/tests/functional_equivalence.rs).
"""

from __future__ import annotations

from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.gemm_pallas import gemm_int8, linear_int8
from .kernels.ref import im2col_ref

Spec = jax.ShapeDtypeStruct
Factory = Tuple[Callable, List[Spec]]


def _tile_for(m: int, k: int, n: int) -> Tuple[int, int, int]:
    """Pick Pallas tile sizes for a GeMM shape.

    Mirrors the paper's design-time (Mu, Ku, Nu) choice: small GeMMs use
    the case-study 8x8x8 array tile; large GeMMs use 32x32x32 tiles so the
    lowered HLO loop nest stays compact (the analogue of picking a larger
    generated array for bigger workloads).
    """
    def pick(d: int) -> int:
        for t in (32, 16, 8):
            if d % t == 0:
                return t
        return 8

    return pick(m), pick(k), pick(n)


def make_gemm(m: int, k: int, n: int) -> Factory:
    """C = A @ B, int8 -> int32, through the Pallas kernel."""
    bm, bk, bn = _tile_for(m, k, n)

    def fn(a, b):
        return (gemm_int8(a, b, bm=bm, bk=bk, bn=bn),)

    return fn, [Spec((m, k), jnp.int8), Spec((k, n), jnp.int8)]


def make_linear(m: int, k: int, n: int) -> Factory:
    """Quantized linear: requant(A @ W + bias) via the fused kernel."""
    bm, bk, bn = _tile_for(m, k, n)

    def fn(a, w, bias, shift):
        return (linear_int8(a, w, bias, shift, bm=bm, bk=bk, bn=bn),)

    return fn, [
        Spec((m, k), jnp.int8),
        Spec((k, n), jnp.int8),
        Spec((n,), jnp.int32),
        Spec((1,), jnp.int32),
    ]


def make_conv_im2col(
    n: int, h: int, w: int, c: int, kh: int, kw: int, k: int, stride: int = 1
) -> Factory:
    """Convolution executed the platform's way: im2col then INT8 GeMM.

    The im2col unfold is part of the lowered graph (the paper runs it as a
    data-layout transformation on the host / DMA side); the GeMM itself is
    the Pallas kernel.
    """
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    gm, gk = n * oh * ow, kh * kw * c
    bm, bk, bn = _tile_for(gm, gk, k)

    def fn(x, wts):
        a = im2col_ref(x, kh, kw, stride)
        b = wts.reshape(kh * kw * c, k)
        out = gemm_int8(a, b, bm=bm, bk=bk, bn=bn)
        return (out.reshape(n, oh, ow, k),)

    return fn, [Spec((n, h, w, c), jnp.int8), Spec((kh, kw, c, k), jnp.int8)]


def make_mha_scores(s: int, d: int, shift: int = 6) -> Factory:
    """Attention scores: requant(Q @ K^T) >> shift, int8 in/out."""
    bm, bk, bn = _tile_for(s, d, s)

    def fn(q, kmat):
        acc = gemm_int8(q, kmat.T, bm=bm, bk=bk, bn=bn)
        half = jnp.int32(1 << (shift - 1)) if shift > 0 else jnp.int32(0)
        rounded = (acc + half) >> shift if shift > 0 else acc
        return (jnp.clip(rounded, -128, 127).astype(jnp.int8),)

    return fn, [Spec((s, d), jnp.int8), Spec((s, d), jnp.int8)]


def make_mlp_block(
    s: int, d: int, hdim: int, shift1: int = 7, shift2: int = 7
) -> Factory:
    """Transformer MLP block: linear -> ReLU -> linear, all int8."""

    def fn(x, w1, b1, w2, b2):
        shift_1 = jnp.asarray([shift1], dtype=jnp.int32)
        shift_2 = jnp.asarray([shift2], dtype=jnp.int32)
        h = linear_int8(x, w1, b1, shift_1)
        h = jnp.maximum(h, jnp.int8(0))
        out = linear_int8(h, w2, b2, shift_2)
        return (out,)

    return fn, [
        Spec((s, d), jnp.int8),
        Spec((d, hdim), jnp.int8),
        Spec((hdim,), jnp.int32),
        Spec((hdim, d), jnp.int8),
        Spec((d,), jnp.int32),
    ]


# ---------------------------------------------------------------------------
# Artifact manifest: every AOT module the Rust platform loads at start-up.
# Keep in sync with rust/src/runtime/artifacts.rs (ARTIFACT_NAMES).
# ---------------------------------------------------------------------------

def artifact_registry() -> dict:
    """name -> (factory fn, factory args) for every AOT artifact."""
    reg = {}
    # Square GeMMs spanning the Fig. 7 sweep range.
    for dim in (8, 16, 32, 64, 128, 256):
        reg[f"gemm_{dim}x{dim}x{dim}"] = (make_gemm, (dim, dim, dim))
    # Irregular shapes (spatial-underutilization path: padding exercised).
    reg["gemm_13x22x17"] = (make_gemm, (13, 22, 17))
    reg["gemm_100x60x40"] = (make_gemm, (100, 60, 40))
    # Fused quantized linear.
    reg["linear_64x64x64"] = (make_linear, (64, 64, 64))
    # Conv-as-GeMM (a ResNet-ish 3x3 layer slice).
    reg["conv_1x16x16x16_3x3x16"] = (make_conv_im2col, (1, 16, 16, 16, 3, 3, 16))
    # Transformer blocks (BERT-ish head slice).
    reg["mha_scores_s64_d64"] = (make_mha_scores, (64, 64))
    reg["mlp_s32_d64_h128"] = (make_mlp_block, (32, 64, 128))
    return reg
