//! Property-based testing helper (proptest is unavailable offline).
//!
//! `property(name, cases, f)` runs `f` against `cases` independently
//! seeded PRNGs; a failure reports the exact case seed so it can be
//! replayed deterministically with `replay(seed, f)`. No shrinking — the
//! generators in this codebase draw small structured values, so failing
//! cases are already readable.

use super::rng::Pcg32;

/// Run a property over `cases` random cases. Panics (with the replay
/// seed) on the first failure.
pub fn property<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    // Derive per-case seeds from the property name so adding properties
    // does not perturb existing ones.
    let name_hash = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = name_hash ^ (case.wrapping_mul(0x9e3779b97f4a7c15));
        let mut rng = Pcg32::seeded(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property {name:?} failed on case {case} (replay seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F>(seed: u64, mut f: F)
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    let mut rng = Pcg32::seeded(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("replayed case (seed {seed:#x}) failed: {msg}");
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Equality assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr, $($fmt:tt)*) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} (left={:?}, right={:?})",
                format!($($fmt)*),
                a,
                b
            ));
        }
    }};
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut hash: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        hash ^= b as u64;
        hash = hash.wrapping_mul(0x100000001b3);
    }
    hash
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        property("always-true", 25, |_rng| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        property("always-false", 5, |_rng| Err("nope".into()));
    }

    #[test]
    fn prop_macros_work() {
        property("macros", 10, |rng| {
            let v = rng.below(10);
            prop_assert!(v < 10, "v out of range: {v}");
            prop_assert_eq!(v, v, "identity");
            Ok(())
        });
    }

    #[test]
    fn seeds_stable_across_runs() {
        let mut first: Vec<u32> = Vec::new();
        property("stability", 3, |rng| {
            first.push(rng.next_u32());
            Ok(())
        });
        let mut second: Vec<u32> = Vec::new();
        property("stability", 3, |rng| {
            second.push(rng.next_u32());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
