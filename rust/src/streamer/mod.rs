//! Data streamers between the multi-banked SPM and the GeMM core
//! (Sec. 3.1/3.3/3.4): programmable hardware loops for autonomous,
//! streaming data access, with input pre-fetch FIFOs and output buffers.

pub mod agu;
pub mod fifo;

pub use agu::{AguConfig, BankPattern};
pub use fifo::Fifo;

/// Temporal loop bounds shared by streamers and the core's loop
/// controller: (M/Mu, N/Nu, K/Ku) tile counts, k1 innermost.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct LoopBounds {
    pub mt: u64,
    pub nt: u64,
    pub kt: u64,
}

impl LoopBounds {
    pub fn total_tiles(&self) -> u64 {
        self.mt * self.nt * self.kt
    }

    pub fn output_tiles(&self) -> u64 {
        self.mt * self.nt
    }

    /// Linear tile position -> (m1, n1, k1); k1 fastest (output
    /// stationary), then n1, then m1.
    #[inline]
    pub fn decompose(&self, pos: u64) -> (u64, u64, u64) {
        let k1 = pos % self.kt;
        let n1 = (pos / self.kt) % self.nt;
        let m1 = pos / (self.kt * self.nt);
        (m1, n1, k1)
    }
}

/// Reusable tile-buffer pool — the zero-copy operand-staging arena.
///
/// Functional simulation moves one A' + one B' tile into the core and
/// one C' tile out of it per output tile; the seed allocated a fresh
/// `Box` for every one of them. The platform owns one `TileArena`
/// instead: tile fetches acquire a buffer here, the core releases the
/// operand buffers right after the tile-MAC consumes them, and the
/// output-commit path releases the C' buffer after the SPM write — so a
/// steady-state run recycles a handful of buffers with zero allocator
/// traffic.
///
/// Contract: buffers come back **dirty** (callers must fully overwrite
/// them, which every producer in the data plane does), and a request
/// whose length has no pooled match just falls through to a fresh
/// allocation (platform reconfiguration between jobs).
#[derive(Debug, Default)]
pub struct TileArena {
    i8_free: Vec<Box<[i8]>>,
    i32_free: Vec<Box<[i32]>>,
    /// Fresh heap allocations served (telemetry: plateaus per run).
    pub allocs: u64,
    /// Requests served from the free lists.
    pub reuses: u64,
}

/// Free-list bound: beyond this, released buffers are simply dropped
/// (a platform never has more than streamer-depth + in-flight tiles
/// live, so the cap is generous).
const ARENA_MAX_POOLED: usize = 64;

impl TileArena {
    pub fn new() -> TileArena {
        TileArena::default()
    }

    /// Acquire an i8 tile buffer of exactly `len` (contents undefined).
    pub fn acquire_i8(&mut self, len: usize) -> Box<[i8]> {
        if let Some(pos) = self.i8_free.iter().rposition(|b| b.len() == len) {
            self.reuses += 1;
            self.i8_free.swap_remove(pos)
        } else {
            self.allocs += 1;
            vec![0i8; len].into_boxed_slice()
        }
    }

    /// Return an i8 buffer to the pool.
    pub fn release_i8(&mut self, buf: Box<[i8]>) {
        if self.i8_free.len() < ARENA_MAX_POOLED {
            self.i8_free.push(buf);
        }
    }

    /// Acquire an i32 tile buffer of exactly `len` (contents undefined).
    pub fn acquire_i32(&mut self, len: usize) -> Box<[i32]> {
        if let Some(pos) = self.i32_free.iter().rposition(|b| b.len() == len) {
            self.reuses += 1;
            self.i32_free.swap_remove(pos)
        } else {
            self.allocs += 1;
            vec![0i32; len].into_boxed_slice()
        }
    }

    /// Return an i32 buffer to the pool.
    pub fn release_i32(&mut self, buf: Box<[i32]>) {
        if self.i32_free.len() < ARENA_MAX_POOLED {
            self.i32_free.push(buf);
        }
    }
}

/// An input tile in flight: its temporal position plus (in functional
/// mode) the fetched bytes.
#[derive(Debug, Clone)]
pub struct InTile {
    pub m1: u64,
    pub n1: u64,
    pub k1: u64,
    pub data: Option<Box<[i8]>>,
}

/// A result tile awaiting writeback.
#[derive(Debug, Clone)]
pub struct OutTile {
    pub m1: u64,
    pub n1: u64,
    pub data: Option<Box<[i32]>>,
}

/// Input streamer state machine (one for A, one for B).
///
/// With pre-fetching enabled it issues a new tile fetch whenever its FIFO
/// has room (the producer side of the paper's producer-consumer buffer);
/// without, it fetches only when the core is starved (Arch(1)/(2)
/// on-demand behaviour).
#[derive(Debug, Clone)]
pub struct InputStreamer {
    pub agu: AguConfig,
    pub bounds: LoopBounds,
    fifo: Fifo<InTile>,
    next_pos: u64,
    /// In-flight fetches: (completion cycle, tile), issue order.
    inflight: std::collections::VecDeque<(u64, InTile)>,
    /// Earliest cycle the streamer may issue its next fetch (its target
    /// banks are busy until then).
    pub issue_gate: u64,
    /// Precomputed bank pattern (timing-only fast path).
    pub pattern: Option<BankPattern>,
    pub prefetch: bool,
    /// Cycles this streamer spent with at least one request in flight.
    pub fetch_busy_cycles: u64,
}

impl InputStreamer {
    pub fn new(depth: usize, prefetch: bool) -> InputStreamer {
        InputStreamer {
            agu: AguConfig::default(),
            bounds: LoopBounds::default(),
            fifo: Fifo::new(depth.max(1)),
            next_pos: 0,
            inflight: std::collections::VecDeque::new(),
            issue_gate: 0,
            pattern: None,
            prefetch,
            fetch_busy_cycles: 0,
        }
    }

    /// Program the streamer for a new run (the CSR "streamer config").
    /// `word_bytes`/`n_bank` let the streamer precompute its bank
    /// pattern for the timing-only fast path.
    pub fn configure2(&mut self, agu: AguConfig, bounds: LoopBounds, word_bytes: u64, n_bank: usize) {
        assert!(self.inflight.is_empty(), "reconfigure while fetch in flight");
        self.agu = agu;
        self.bounds = bounds;
        self.next_pos = 0;
        self.pattern = agu.bank_pattern(word_bytes, n_bank);
        self.fifo.clear();
    }

    /// Program the streamer (tests / no fast path).
    pub fn configure(&mut self, agu: AguConfig, bounds: LoopBounds) {
        self.configure2(agu, bounds, 8, 1 << 30); // pattern disabled
    }

    /// Timing-only issue: advance to the next tile and return its
    /// position and base byte address (no address materialization).
    pub fn begin_fetch_timing(&mut self) -> ((u64, u64, u64), i64) {
        debug_assert!(!self.done_fetching());
        let pos = self.bounds.decompose(self.next_pos);
        self.next_pos += 1;
        (pos, self.agu.tile_base(pos.0, pos.1, pos.2))
    }

    pub fn done_fetching(&self) -> bool {
        self.next_pos >= self.bounds.total_tiles()
    }

    pub fn fifo_len(&self) -> usize {
        self.fifo.len()
    }

    pub fn fifo_peak(&self) -> usize {
        self.fifo.peak
    }

    pub fn inflight_len(&self) -> usize {
        self.inflight.len()
    }

    pub fn has_outstanding(&self) -> bool {
        !self.inflight.is_empty()
    }

    /// Should a new fetch be issued at cycle `now`? `core_starved` is
    /// true when the core is waiting on this streamer's tile.
    ///
    /// With pre-fetching the streamer pipelines requests: up to
    /// `capacity` tiles may be in flight + buffered (the producer side
    /// of the paper's producer-consumer buffer). Without, it fetches
    /// one tile at a time, on demand (Arch1/2 behaviour).
    pub fn wants_fetch(&self, now: u64, core_starved: bool) -> bool {
        if self.done_fetching() || now < self.issue_gate {
            return false;
        }
        if self.fifo.len() + self.inflight.len() >= self.fifo.capacity() {
            return false;
        }
        if self.prefetch {
            true
        } else {
            // On-demand: one outstanding max, only when the consumer is
            // actually waiting.
            core_starved && self.fifo.is_empty() && self.inflight.is_empty()
        }
    }

    /// Issue the next tile fetch; emits the word addresses into `addrs`.
    /// The platform computes the service time and calls
    /// [`InputStreamer::commit_fetch`] with the completion cycle.
    pub fn begin_fetch(&mut self, word_bytes: u64, addrs: &mut Vec<u64>) -> (u64, u64, u64) {
        debug_assert!(!self.done_fetching());
        let (m1, n1, k1) = self.bounds.decompose(self.next_pos);
        self.agu.tile_word_addrs(m1, n1, k1, word_bytes, addrs);
        self.next_pos += 1;
        (m1, n1, k1)
    }

    /// Commit the fetch issued by `begin_fetch`.
    pub fn commit_fetch(
        &mut self,
        pos: (u64, u64, u64),
        data: Option<Box<[i8]>>,
        completion: u64,
        bank_free: u64,
    ) {
        // in-order completion: later fetches cannot overtake
        let completion = self
            .inflight
            .back()
            .map(|&(t, _)| t.max(completion))
            .unwrap_or(completion);
        self.inflight.push_back((
            completion,
            InTile { m1: pos.0, n1: pos.1, k1: pos.2, data },
        ));
        self.issue_gate = bank_free;
    }

    /// Completion cycle of the oldest in-flight fetch — the next
    /// delivery event of this streamer (completions are in-order, so
    /// the front of the queue is the earliest). `None` when nothing is
    /// in flight.
    pub fn next_delivery(&self) -> Option<u64> {
        self.inflight.front().map(|&(t, _)| t)
    }

    /// Earliest cycle at which this streamer could issue a new fetch,
    /// assuming the rest of the platform state stays frozen (no
    /// deliveries, no FIFO pops) until then. `None` when no fetch can
    /// become issuable without some other event happening first.
    ///
    /// Invariant used by the fast-forward engine: for any `now`,
    /// `wants_fetch(now, starved)` is equivalent to
    /// `next_issue(starved).is_some_and(|t| now >= t)`.
    pub fn next_issue(&self, core_starved: bool) -> Option<u64> {
        if self.done_fetching() {
            return None;
        }
        if self.fifo.len() + self.inflight.len() >= self.fifo.capacity() {
            return None;
        }
        if !self.prefetch && !(core_starved && self.fifo.is_empty() && self.inflight.is_empty()) {
            return None;
        }
        Some(self.issue_gate)
    }

    /// Move completed fetches into the FIFO.
    pub fn deliver_ready(&mut self, now: u64) {
        while let Some(&(t, _)) = self.inflight.front() {
            if t > now {
                break;
            }
            let (_, tile) = self.inflight.pop_front().unwrap();
            self.fifo.push(tile);
        }
    }

    pub fn head(&self) -> Option<&InTile> {
        self.fifo.peek()
    }

    pub fn pop(&mut self) -> Option<InTile> {
        self.fifo.pop()
    }

    pub fn tick_busy(&mut self) {
        if !self.inflight.is_empty() {
            self.fetch_busy_cycles += 1;
        }
    }
}

/// Output streamer: buffers C' tiles and drains them to the SPM in the
/// background (round-robin over `D_stream` buffers in the RTL; FIFO
/// semantics here). Without output buffering the core blocks on a full
/// buffer of depth 1 until the writeback epoch completes.
#[derive(Debug, Clone)]
pub struct OutputStreamer {
    pub agu: AguConfig,
    buffer: Fifo<OutTile>,
    outstanding: Option<(u64, OutTile)>,
    /// Earliest cycle the writer may start its next writeback.
    pub issue_gate: u64,
    /// Precomputed bank pattern (timing-only fast path).
    pub pattern: Option<BankPattern>,
    pub write_busy_cycles: u64,
}

impl OutputStreamer {
    pub fn new(depth: usize) -> OutputStreamer {
        OutputStreamer {
            agu: AguConfig::default(),
            buffer: Fifo::new(depth.max(1)),
            outstanding: None,
            issue_gate: 0,
            pattern: None,
            write_busy_cycles: 0,
        }
    }

    pub fn configure2(&mut self, agu: AguConfig, word_bytes: u64, n_bank: usize) {
        assert!(self.outstanding.is_none(), "reconfigure while write in flight");
        self.agu = agu;
        self.pattern = agu.bank_pattern(word_bytes, n_bank);
        self.buffer.clear();
    }

    pub fn configure(&mut self, agu: AguConfig) {
        self.configure2(agu, 8, 1 << 30); // pattern disabled
    }

    /// Timing-only writeback issue: pop the oldest tile, return it with
    /// its base byte address.
    pub fn begin_write_timing(&mut self) -> (OutTile, i64) {
        debug_assert!(!self.buffer.is_empty() && self.outstanding.is_none());
        let tile = self.buffer.pop().unwrap();
        let base = self.agu.tile_base(tile.m1, tile.n1, 0);
        (tile, base)
    }

    pub fn can_accept(&self) -> bool {
        !self.buffer.is_full()
    }

    pub fn accept(&mut self, tile: OutTile) {
        self.buffer.push(tile);
    }

    pub fn is_drained(&self) -> bool {
        self.buffer.is_empty() && self.outstanding.is_none()
    }

    pub fn has_outstanding(&self) -> bool {
        self.outstanding.is_some()
    }

    pub fn buffer_len(&self) -> usize {
        self.buffer.len()
    }

    /// Should a writeback start at cycle `now`?
    pub fn wants_write(&self, now: u64) -> bool {
        self.outstanding.is_none() && !self.buffer.is_empty() && now >= self.issue_gate
    }

    /// Start writing the oldest buffered tile; emits word addresses.
    /// The platform supplies the completion cycle via `commit_write`.
    pub fn begin_write(&mut self, word_bytes: u64, addrs: &mut Vec<u64>) -> OutTile {
        debug_assert!(!self.buffer.is_empty() && self.outstanding.is_none());
        let tile = self.buffer.pop().unwrap();
        self.agu.tile_word_addrs(tile.m1, tile.n1, 0, word_bytes, addrs);
        tile
    }

    pub fn commit_write(&mut self, tile: OutTile, completion: u64, bank_free: u64) {
        self.outstanding = Some((completion, tile));
        self.issue_gate = bank_free;
    }

    /// Completion cycle of the outstanding writeback — the next
    /// delivery event of this streamer. `None` when idle.
    pub fn next_delivery(&self) -> Option<u64> {
        self.outstanding.as_ref().map(|&(t, _)| t)
    }

    /// Earliest cycle at which the writer could start its next
    /// writeback, assuming frozen platform state until then (see
    /// [`InputStreamer::next_issue`] for the invariant).
    pub fn next_issue(&self) -> Option<u64> {
        if self.outstanding.is_some() || self.buffer.is_empty() {
            return None;
        }
        Some(self.issue_gate)
    }

    /// Returns the written tile once `now` reaches its completion (for
    /// functional commit to the SPM).
    pub fn deliver_ready(&mut self, now: u64) -> Option<OutTile> {
        if let Some((t, _)) = &self.outstanding {
            if *t <= now {
                return self.outstanding.take().map(|(_, tile)| tile);
            }
        }
        None
    }

    pub fn tick_busy(&mut self) {
        if self.outstanding.is_some() {
            self.write_busy_cycles += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bounds() -> LoopBounds {
        LoopBounds { mt: 2, nt: 3, kt: 4 }
    }

    #[test]
    fn decompose_order_k_fastest() {
        let b = bounds();
        assert_eq!(b.decompose(0), (0, 0, 0));
        assert_eq!(b.decompose(1), (0, 0, 1));
        assert_eq!(b.decompose(4), (0, 1, 0));
        assert_eq!(b.decompose(12), (1, 0, 0));
        assert_eq!(b.decompose(23), (1, 2, 3));
        assert_eq!(b.total_tiles(), 24);
        assert_eq!(b.output_tiles(), 6);
    }

    #[test]
    fn prefetch_streamer_pipelines_up_to_capacity() {
        let mut s = InputStreamer::new(3, true);
        s.configure(AguConfig::linear(0, 2, 8), bounds());
        let mut addrs = Vec::new();
        // may keep issuing until fifo + inflight reach capacity
        for i in 0..3u64 {
            assert!(s.wants_fetch(i, false), "issue {i}");
            let pos = s.begin_fetch(8, &mut addrs);
            s.commit_fetch(pos, None, i + 1, i + 1);
        }
        assert!(!s.wants_fetch(3, false), "capacity reached");
        assert_eq!(s.inflight_len(), 3);
        s.deliver_ready(10);
        assert_eq!(s.fifo_len(), 3);
        assert_eq!(s.inflight_len(), 0);
    }

    #[test]
    fn issue_gate_blocks_next_fetch() {
        let mut s = InputStreamer::new(4, true);
        s.configure(AguConfig::linear(0, 1, 0), bounds());
        let mut addrs = Vec::new();
        let pos = s.begin_fetch(8, &mut addrs);
        // banks busy until cycle 5
        s.commit_fetch(pos, None, 5, 5);
        assert!(!s.wants_fetch(3, false));
        assert!(s.wants_fetch(5, false));
    }

    #[test]
    fn in_order_completion_enforced() {
        let mut s = InputStreamer::new(4, true);
        s.configure(AguConfig::linear(0, 1, 0), bounds());
        let mut addrs = Vec::new();
        let p0 = s.begin_fetch(8, &mut addrs);
        s.commit_fetch(p0, None, 10, 1);
        let p1 = s.begin_fetch(8, &mut addrs);
        // nominally completes at 2, but must not overtake p0
        s.commit_fetch(p1, None, 2, 2);
        s.deliver_ready(9);
        assert_eq!(s.fifo_len(), 0, "nothing ready before 10");
        s.deliver_ready(10);
        assert_eq!(s.fifo_len(), 2, "both deliver at 10, in order");
        assert_eq!(s.pop().unwrap().k1, 0);
        assert_eq!(s.pop().unwrap().k1, 1);
    }

    #[test]
    fn on_demand_streamer_waits_for_core() {
        let mut s = InputStreamer::new(3, false);
        s.configure(AguConfig::linear(0, 1, 0), bounds());
        assert!(!s.wants_fetch(0, false), "no fetch until core starves");
        assert!(s.wants_fetch(0, true));
        let mut addrs = Vec::new();
        let pos = s.begin_fetch(8, &mut addrs);
        s.commit_fetch(pos, None, 1, 1);
        assert!(!s.wants_fetch(1, true), "one outstanding max");
        s.deliver_ready(1);
        assert_eq!(s.fifo_len(), 1);
        assert!(!s.wants_fetch(2, true), "FIFO non-empty");
    }

    #[test]
    fn fetch_sequence_covers_all_tiles_in_order() {
        let b = bounds();
        let mut s = InputStreamer::new(2, true);
        s.configure(AguConfig::linear(0, 1, 0), b);
        let mut addrs = Vec::new();
        let mut seen = Vec::new();
        let mut now = 0u64;
        while !(s.done_fetching() && s.fifo_len() == 0 && s.inflight_len() == 0) {
            if s.wants_fetch(now, false) {
                let pos = s.begin_fetch(8, &mut addrs);
                s.commit_fetch(pos, None, now + 1, now + 1);
            }
            s.deliver_ready(now);
            if let Some(t) = s.pop() {
                seen.push((t.m1, t.n1, t.k1));
            }
            now += 1;
        }
        let expect: Vec<_> = (0..b.total_tiles()).map(|p| b.decompose(p)).collect();
        assert_eq!(seen, expect);
    }

    #[test]
    fn next_issue_agrees_with_wants_fetch() {
        // the fast-forward engine relies on this equivalence
        for prefetch in [false, true] {
            let mut s = InputStreamer::new(2, prefetch);
            s.configure(AguConfig::linear(0, 1, 0), bounds());
            let mut addrs = Vec::new();
            for now in 0..40u64 {
                for starved in [false, true] {
                    let via_next = s.next_issue(starved).map(|t| now >= t).unwrap_or(false);
                    assert_eq!(
                        s.wants_fetch(now, starved),
                        via_next,
                        "prefetch={prefetch} now={now} starved={starved}"
                    );
                }
                if s.wants_fetch(now, true) {
                    let pos = s.begin_fetch(8, &mut addrs);
                    s.commit_fetch(pos, None, now + 3, now + 2);
                }
                assert_eq!(s.next_delivery(), s.inflight.front().map(|&(t, _)| t));
                if now % 3 == 0 {
                    s.deliver_ready(now);
                    let _ = s.pop();
                }
            }
        }
    }

    #[test]
    fn output_next_issue_agrees_with_wants_write() {
        let mut o = OutputStreamer::new(2);
        o.configure(AguConfig::linear(0, 1, 0));
        assert_eq!(o.next_issue(), None, "empty buffer: nothing to write");
        o.accept(OutTile { m1: 0, n1: 0, data: None });
        let mut addrs = Vec::new();
        for now in 0..10u64 {
            let via_next = o.next_issue().map(|t| now >= t).unwrap_or(false);
            assert_eq!(o.wants_write(now), via_next, "now={now}");
        }
        let tile = o.begin_write(8, &mut addrs);
        o.commit_write(tile, 5, 4);
        assert_eq!(o.next_delivery(), Some(5));
        assert_eq!(o.next_issue(), None, "outstanding write blocks issue");
    }

    #[test]
    fn arena_recycles_matching_sizes() {
        let mut arena = TileArena::new();
        let b0 = arena.acquire_i8(64);
        let b1 = arena.acquire_i8(64);
        assert_eq!(arena.allocs, 2);
        arena.release_i8(b0);
        arena.release_i8(b1);
        let b2 = arena.acquire_i8(64);
        assert_eq!(b2.len(), 64);
        assert_eq!(arena.reuses, 1);
        // size mismatch falls through to a fresh allocation
        let b3 = arena.acquire_i8(128);
        assert_eq!(b3.len(), 128);
        assert_eq!(arena.allocs, 3);
        let c0 = arena.acquire_i32(64);
        arena.release_i32(c0);
        let c1 = arena.acquire_i32(64);
        assert_eq!(c1.len(), 64);
        assert_eq!(arena.reuses, 2);
    }

    #[test]
    fn output_streamer_backpressure() {
        let mut o = OutputStreamer::new(2);
        o.configure(AguConfig::linear(0, 1, 0));
        assert!(o.can_accept());
        o.accept(OutTile { m1: 0, n1: 0, data: None });
        o.accept(OutTile { m1: 0, n1: 1, data: None });
        assert!(!o.can_accept(), "buffer full");
        let mut addrs = Vec::new();
        assert!(o.wants_write(0));
        let tile = o.begin_write(8, &mut addrs);
        o.commit_write(tile, 2, 2);
        assert!(o.can_accept(), "popped into outstanding");
        assert!(o.deliver_ready(1).is_none(), "not done yet");
        let t = o.deliver_ready(2).expect("write completes at 2");
        assert_eq!((t.m1, t.n1), (0, 0));
        assert!(!o.is_drained());
    }

    #[test]
    fn output_addresses_use_mn_position() {
        let mut o = OutputStreamer::new(1);
        o.configure(AguConfig {
            base: 0,
            stride_m: 1024,
            stride_n: 32,
            stride_k: 0,
            spatial0_count: 4,
            spatial0_stride: 8,
            spatial1_count: 1,
            spatial1_stride: 0,
        });
        o.accept(OutTile { m1: 2, n1: 3, data: None });
        let mut addrs = Vec::new();
        let tile = o.begin_write(8, &mut addrs);
        o.commit_write(tile, 1, 1);
        // base = 2*1024 + 3*32 = 2144 bytes -> word 268
        assert_eq!(addrs, vec![268, 269, 270, 271]);
    }
}
