//! Bench: regenerate Fig. 7 — area-normalized throughput of OpenGeMM
//! vs Gemmini (OS and WS modes) across square GeMM sizes 8..128.
//!
//! Run with:  cargo bench --bench fig7_gemmini

use std::time::Instant;

use opengemm::config::PlatformConfig;
use opengemm::experiments::{fig7_gemmini, Fig7Options};

fn main() {
    let cfg = PlatformConfig::case_study();
    let t0 = Instant::now();
    let res = fig7_gemmini(&cfg, Fig7Options::default());
    println!("{}", res.render());
    println!("bench fig7_gemmini: {:.2}s wall", t0.elapsed().as_secs_f64());
}
