//! Bench: regenerate Table 3 — the state-of-the-art comparison, with
//! the OpenGeMM row produced by the area/power model.
//!
//! Run with:  cargo bench --bench table3_sota

use std::time::Instant;

use opengemm::config::PlatformConfig;
use opengemm::experiments::table3_sota;

fn main() {
    let cfg = PlatformConfig::case_study();
    let t0 = Instant::now();
    let res = table3_sota(&cfg);
    println!("{}", res.render());
    println!("bench table3_sota: {:.3}s wall", t0.elapsed().as_secs_f64());
}
